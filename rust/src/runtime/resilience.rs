//! Resident-state resilience: checkpointing, deterministic fault
//! injection, and supervised recovery for the farm/plane runtime.
//!
//! # Why this layer exists
//!
//! PERKS' whole premise is moving the time loop *into* a persistent
//! runtime so solver state stays resident ([`crate::runtime::farm`]) —
//! which means a single worker panic, NaN contamination, or stuck shard
//! now destroys hours of resident progress instead of one kernel launch.
//! Batching an entire `advance_until` schedule into one
//! [`crate::runtime::plane::CommandGraph`] widens that blast radius
//! further: the longer the resident schedule, the more there is to lose.
//! This module is the in-process recovery machinery that closes the gap,
//! in three pieces:
//!
//! 1. **Epoch-boundary checkpointing.** A tenant configured with a
//!    [`ResilienceConfig::checkpoint_every`] cadence snapshots its
//!    resident state (stencil: grid + slab pairs + step counters; CG:
//!    x/r/p + recurrence scalars) into a per-tenant [`Checkpoint`] —
//!    a cheap copy taken *under the already-held scheduler lock* at the
//!    existing countdown transition, so no extra barrier or phase is
//!    ever added. A command-entry checkpoint is taken whenever a
//!    [`RetryPolicy`] is armed, so recovery is possible at **any**
//!    epoch, not just past the first cadence boundary.
//!
//! 2. **Deterministic fault injection.** A [`FaultPlan`] names exact
//!    (tenant, epoch, phase, shard) coordinates at which to inject a
//!    worker panic, NaN poisoning of resident state, or an artificial
//!    stall. The plan is consulted at task-claim time, under the
//!    scheduler lock the claim already holds — when no plan is
//!    installed the entire feature is one `Option` check (zero cost on
//!    the hot path). Plans are seeded/replayable: build them in code
//!    ([`FaultSpec`] builders, [`FaultPlan::seeded`]) or from the
//!    `PERKS_FAULT_PLAN` environment variable so CI can replay any
//!    failure coordinate verbatim ([`FaultPlan::from_env`]).
//!
//! 3. **Supervised recovery.** With a [`RetryPolicy`] armed, a panicked
//!    or NaN-tripped command no longer errors the session: the farm
//!    restores the last checkpoint (state bytes *and* traffic
//!    accounting) and replays the remaining schedule. Because every
//!    farm reduction folds fixed slots in slot order, the replay is
//!    **bit-identical** to an uninjected run — the determinism story of
//!    PRs 2–6 is exactly what makes recovery checkable. Exhausted
//!    retries (or a disabled policy) surface the structured
//!    [`crate::Error::Fault`] / non-finite `Error::Solver` instead; a
//!    blocking wait with a [`ResilienceConfig::deadline`] watchdog
//!    surfaces [`crate::Error::Stuck`] when a command exceeds it, and
//!    the stuck command is reaped through the existing zombie path when
//!    the client releases the tenant.
//!
//! 4. **Durable snapshots.** The [`snapshot`] submodule persists the
//!    same [`Checkpoint`]s crash-consistently to disk (serialize to
//!    `*.tmp`, fsync, atomic rename into a checksummed
//!    generation-numbered frame, versioned manifest), armed per tenant
//!    via [`ResilienceConfig::durable`] /
//!    `SessionBuilder::durable(dir)`. That extends recovery past the
//!    process boundary: a [`FaultKind::Kill`], SIGKILL, OOM kill, or
//!    node reboot is survivable because a *fresh* process (the
//!    `perks_recover` binary, or any client) restores the newest
//!    verifiable generation and resumes bit-identical. The write-out
//!    runs outside the scheduler lock so the hot path never waits on
//!    `fsync`. See `docs/RECOVERY.md` for the on-disk format and the
//!    crash-consistency argument.
//!
//! Failure classes injectable (and recoverable) here:
//!
//! * [`FaultKind::Panic`] — the shard closure panics; caught by the
//!   worker, surfaced as `Error::Fault { phase, shard, epoch }`.
//! * [`FaultKind::Nan`] — the shard's resident output is poisoned with
//!   a NaN after it runs; the non-finite guards on the residual /
//!   `p·Ap` / `r·r` folds detect it at the next reduction.
//! * [`FaultKind::Stall`] — the worker sleeps before running the
//!   shard, exercising the wait-side watchdog deadline.
//! * [`FaultKind::Kill`] — the worker hard-aborts the whole process
//!   (`std::process::abort`) at the matched claim site: no unwinding,
//!   no in-process recovery. Only a durable snapshot directory makes
//!   this one survivable; it drives the crash-restart CI job.
//!
//! The solo pools participate too: [`crate::stencil::pool::StencilPool`]
//! exposes `checkpoint`/`restore` over the same [`Checkpoint`] type
//! (its grid is whole-band-stored at every park, so a snapshot between
//! runs is always consistent). `CgPool` needs no pool-side checkpoint:
//! its x/r/p state round-trips through the caller on every `run`, so a
//! caller-side clone of those vectors *is* the checkpoint.

use std::path::PathBuf;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::rng::Rng;

pub mod snapshot;

/// Default checkpoint cadence, in epochs (stencil exchange epochs / CG
/// iterations). Chosen so the copy cost stays well under the 5%-of-wall
/// acceptance bar on the bench workloads while bounding replay work.
pub const DEFAULT_CHECKPOINT_EVERY: u64 = 16;

// ---------------------------------------------------------------------
// Retry policy + per-tenant config
// ---------------------------------------------------------------------

/// Supervised-recovery policy: how many times a retryable failure
/// (injected or real panic, non-finite reduction) restores the last
/// checkpoint and replays, and how long to back off before each replay.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Restore-and-replay attempts per command; 0 disables recovery
    /// (failures surface immediately as structured errors).
    pub max_attempts: u32,
    /// Delay before a restored tenant becomes claimable again. The
    /// scheduler defers the tenant without blocking any worker; zero
    /// (the default) replays immediately.
    pub backoff: Duration,
}

impl RetryPolicy {
    /// No recovery: failures surface as errors (the pre-resilience
    /// behavior, minus the stringly errors).
    pub const fn disabled() -> Self {
        Self { max_attempts: 0, backoff: Duration::ZERO }
    }

    /// Recover up to `max_attempts` times with no backoff.
    pub const fn attempts(max_attempts: u32) -> Self {
        Self { max_attempts, backoff: Duration::ZERO }
    }

    /// Set the replay backoff.
    pub const fn with_backoff(mut self, backoff: Duration) -> Self {
        self.backoff = backoff;
        self
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::disabled()
    }
}

/// Per-tenant resilience knobs, set through
/// `FarmStencil::configure_resilience` / `FarmCg::configure_resilience`
/// (or `SessionBuilder::{checkpoint_every, retry, command_deadline}`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResilienceConfig {
    /// Checkpoint the resident state every this many completed epochs
    /// (stencil exchange epochs / CG iterations); 0 disables cadence
    /// checkpoints. Independent of `retry`: a command-entry checkpoint
    /// is always taken when `retry.max_attempts > 0`, so recovery works
    /// even with the cadence off (it just replays from the command
    /// boundary).
    pub checkpoint_every: u64,
    /// Supervised-recovery policy for retryable failures.
    pub retry: RetryPolicy,
    /// Watchdog deadline for the *blocking* wait paths: a command still
    /// in flight after this long fails the wait with
    /// [`crate::Error::Stuck`] (phase/epoch context attached). The
    /// command itself keeps draining; releasing the tenant reaps it as
    /// a zombie through the farm's existing release path.
    pub deadline: Option<Duration>,
    /// Durable snapshot directory: when set, every checkpoint this
    /// config takes (cadence and command-entry) is also persisted
    /// crash-consistently under this directory by a
    /// [`snapshot::SnapshotStore`], outside the scheduler lock. `None`
    /// (the default) keeps checkpoints purely in-memory — the
    /// zero-filesystem behavior of PR 7.
    pub durable: Option<PathBuf>,
}

impl ResilienceConfig {
    /// Everything off — the zero-overhead default.
    pub const fn disabled() -> Self {
        Self {
            checkpoint_every: 0,
            retry: RetryPolicy::disabled(),
            deadline: None,
            durable: None,
        }
    }

    /// Cadence checkpoints at [`DEFAULT_CHECKPOINT_EVERY`], recovery and
    /// watchdog off — the checkpoint-overhead bench arm.
    pub const fn checkpointed() -> Self {
        Self {
            checkpoint_every: DEFAULT_CHECKPOINT_EVERY,
            retry: RetryPolicy::disabled(),
            deadline: None,
            durable: None,
        }
    }

    /// The production serving shape: default cadence plus recovery with
    /// `attempts` replays.
    pub const fn recovering(attempts: u32) -> Self {
        Self {
            checkpoint_every: DEFAULT_CHECKPOINT_EVERY,
            retry: RetryPolicy::attempts(attempts),
            deadline: None,
            durable: None,
        }
    }

    /// Set the checkpoint cadence.
    pub const fn every(mut self, epochs: u64) -> Self {
        self.checkpoint_every = epochs;
        self
    }

    /// Set the retry policy.
    pub const fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Set the blocking-wait watchdog deadline.
    pub const fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Persist checkpoints crash-consistently under `dir` (see
    /// [`snapshot`]). Durable frames are only written when a checkpoint
    /// is actually taken, so this composes with [`Self::every`]: cadence
    /// 0 plus a retry-disabled policy writes zero frames.
    pub fn durable(mut self, dir: impl Into<PathBuf>) -> Self {
        self.durable = Some(dir.into());
        self
    }

    /// Any knob armed? (Used by `SessionBuilder` validation: these are
    /// farm-session knobs, meaningless on solo substrates.)
    pub fn enabled(&self) -> bool {
        self.checkpoint_every > 0
            || self.retry.max_attempts > 0
            || self.deadline.is_some()
            || self.durable.is_some()
    }
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self::disabled()
    }
}

// ---------------------------------------------------------------------
// Checkpoints
// ---------------------------------------------------------------------

/// A point-in-time snapshot of one tenant's resident state, restorable
/// bit-for-bit. Construction is internal (the farm and the solo stencil
/// pool take them); the public surface is the metadata plus restore
/// entry points on the owning substrate.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Completed-epoch coordinate the snapshot was taken at (stencil
    /// exchange epochs / CG iterations, counted over the tenant's
    /// lifetime).
    pub epoch: u64,
    /// Payload size in bytes (what `checkpoint_bytes` counters count).
    pub bytes: u64,
    pub(crate) payload: CheckpointPayload,
}

/// The engine-specific bytes of a checkpoint.
#[derive(Clone, Debug)]
pub(crate) enum CheckpointPayload {
    Stencil {
        grid: Vec<f64>,
        /// (cur, nxt) per band; empty while the slabs were never loaded
        /// (a command-entry snapshot before the first `P_LOAD`).
        slabs: Vec<(Vec<f64>, Vec<f64>)>,
        done_steps: usize,
        residual: Option<f64>,
        loaded: bool,
        /// Traffic accounting at the snapshot point, restored with the
        /// state so a recovered run reports the same bytes/cells as a
        /// clean one.
        moved: u64,
        computed: u64,
        /// Command schedule at the snapshot point: target step count and
        /// the remaining graph segments (+ resubmit count). Replaying
        /// with the *same* segment schedule keeps sub-step grouping —
        /// and hence per-epoch accounting — identical to the clean run.
        steps_target: usize,
        segs: Vec<usize>,
        resubmits: u32,
    },
    Cg {
        x: Vec<f64>,
        r: Vec<f64>,
        p: Vec<f64>,
        rr: f64,
        iters_done: usize,
        /// Command schedule at the snapshot point (see the stencil arm).
        iters_target: usize,
        segs: Vec<usize>,
        resubmits: u32,
    },
}

impl CheckpointPayload {
    fn bytes(&self) -> u64 {
        match self {
            CheckpointPayload::Stencil { grid, slabs, .. } => {
                let slab: usize = slabs.iter().map(|(c, n)| c.len() + n.len()).sum();
                ((grid.len() + slab) * 8) as u64
            }
            CheckpointPayload::Cg { x, r, p, .. } => ((x.len() + r.len() + p.len()) * 8) as u64,
        }
    }
}

impl Checkpoint {
    pub(crate) fn new(epoch: u64, payload: CheckpointPayload) -> Self {
        let bytes = payload.bytes();
        Self { epoch, bytes, payload }
    }

    /// Which engine's payload this snapshot holds: `"stencil"` or
    /// `"cg"`. Stable strings — `perks_recover list` prints them and
    /// the snapshot manifest round-trips the same discriminant.
    pub fn kind(&self) -> &'static str {
        match self.payload {
            CheckpointPayload::Stencil { .. } => "stencil",
            CheckpointPayload::Cg { .. } => "cg",
        }
    }

    /// `(completed, target)` progress of the command the snapshot was
    /// taken in: stencil sub-steps done/target, or CG iterations
    /// done/target.
    pub fn progress(&self) -> (usize, usize) {
        match &self.payload {
            CheckpointPayload::Stencil { done_steps, steps_target, .. } => {
                (*done_steps, *steps_target)
            }
            CheckpointPayload::Cg { iters_done, iters_target, .. } => (*iters_done, *iters_target),
        }
    }

    /// Clone out a CG payload's caller-side state `(x, r, p, rr,
    /// iters_done)` — exactly what `FarmCg::run` round-trips through
    /// the caller, so a restored client resumes by handing these back.
    /// `None` for a stencil snapshot (stencil state is resident; use
    /// `FarmStencil::restore_from` instead).
    pub fn cg_state(&self) -> Option<(Vec<f64>, Vec<f64>, Vec<f64>, f64, usize)> {
        match &self.payload {
            CheckpointPayload::Cg { x, r, p, rr, iters_done, .. } => {
                Some((x.clone(), r.clone(), p.clone(), *rr, *iters_done))
            }
            CheckpointPayload::Stencil { .. } => None,
        }
    }
}

// ---------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------

/// What an injected fault does when its coordinate is claimed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker panics inside the shard closure (caught, surfaced as
    /// [`crate::Error::Fault`] or recovered under the retry policy).
    Panic,
    /// The shard runs normally, then its resident output is poisoned
    /// with a NaN — detected by the non-finite guards at the next
    /// residual / `p·Ap` / `r·r` fold.
    Nan,
    /// The worker sleeps this long before running the shard, exercising
    /// the blocking-wait watchdog ([`ResilienceConfig::deadline`]).
    Stall(Duration),
    /// The worker hard-aborts the whole process (`std::process::abort`)
    /// at the matched claim site — no unwinding, no destructor, no
    /// in-process recovery possible. This is the SIGKILL stand-in for
    /// the crash-restart path: only a durable snapshot directory
    /// ([`snapshot`]) makes the tenant's progress survivable, restored
    /// by a fresh process via `perks_recover`.
    Kill,
}

/// One fault coordinate. `epoch` is always explicit; tenant/phase/shard
/// default to wildcards so a plan can say "panic whichever shard runs
/// first in epoch 3" or pin every coordinate for exact CI replay.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Completed-epoch coordinate (the tenant's lifetime epoch counter
    /// at claim time; CG iterations count as epochs).
    pub epoch: u64,
    /// Tenant slot id (admission order in a fresh farm), `None` = any.
    pub tenant: Option<usize>,
    /// Phase constant of the target engine (`farm::P_*`), `None` = any.
    pub phase: Option<u8>,
    /// Shard index, `None` = any.
    pub shard: Option<usize>,
    /// Fired flag: every spec injects exactly once, so a recovered
    /// replay of the same coordinates runs clean — which is what makes
    /// the recovered-equals-clean property testable.
    fired: bool,
}

impl FaultSpec {
    /// A worker panic at `epoch` (wildcard tenant/phase/shard).
    pub fn panic_at(epoch: u64) -> Self {
        Self { kind: FaultKind::Panic, epoch, tenant: None, phase: None, shard: None, fired: false }
    }

    /// NaN poisoning at `epoch`.
    pub fn nan_at(epoch: u64) -> Self {
        Self { kind: FaultKind::Nan, epoch, tenant: None, phase: None, shard: None, fired: false }
    }

    /// An artificial stall of `d` at `epoch`.
    pub fn stall_at(epoch: u64, d: Duration) -> Self {
        Self {
            kind: FaultKind::Stall(d),
            epoch,
            tenant: None,
            phase: None,
            shard: None,
            fired: false,
        }
    }

    /// A hard process abort at `epoch` (wildcard tenant/phase/shard).
    /// Recoverable only through a durable snapshot directory.
    pub fn kill_at(epoch: u64) -> Self {
        Self { kind: FaultKind::Kill, epoch, tenant: None, phase: None, shard: None, fired: false }
    }

    /// Pin the tenant slot.
    pub fn tenant(mut self, tid: usize) -> Self {
        self.tenant = Some(tid);
        self
    }

    /// Pin the phase (`farm::P_*` constants).
    pub fn phase(mut self, phase: u8) -> Self {
        self.phase = Some(phase);
        self
    }

    /// Pin the shard.
    pub fn shard(mut self, shard: usize) -> Self {
        self.shard = Some(shard);
        self
    }

    fn matches(&self, tenant: usize, epoch: u64, phase: u8, shard: usize) -> bool {
        !self.fired
            && self.epoch == epoch
            && self.tenant.map_or(true, |t| t == tenant)
            && self.phase.map_or(true, |p| p == phase)
            && self.shard.map_or(true, |s| s == shard)
    }
}

/// A deterministic injection schedule: a list of [`FaultSpec`]s, each
/// firing exactly once when its coordinate is claimed. Installed on a
/// farm with `SolverFarm::install_faults` (or automatically from the
/// `PERKS_FAULT_PLAN` environment variable at spawn), consulted under
/// the scheduler lock at task-claim time — no plan, no cost.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    faults: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one fault coordinate (builder style).
    pub fn inject(mut self, spec: FaultSpec) -> Self {
        self.faults.push(spec);
        self
    }

    /// Derive one panic-or-NaN fault from a seed, uniformly over
    /// `epoch < epochs` and `shard < shards` (wildcard tenant/phase) —
    /// the property-test generator: any seed names a replayable fault.
    pub fn seeded(seed: u64, epochs: u64, shards: usize) -> Self {
        let mut rng = Rng::new(seed);
        let epoch = rng.below(epochs.max(1));
        let shard = rng.index(shards.max(1));
        let spec = match rng.below(2) {
            0 => FaultSpec::panic_at(epoch),
            _ => FaultSpec::nan_at(epoch),
        };
        Self::new().inject(spec.shard(shard))
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// Faults that have not fired yet.
    pub fn pending(&self) -> usize {
        self.faults.iter().filter(|f| !f.fired).count()
    }

    /// Claim the first unfired fault matching the coordinate, marking it
    /// fired. Called by the farm scheduler under its lock.
    pub(crate) fn claim(
        &mut self,
        tenant: usize,
        epoch: u64,
        phase: u8,
        shard: usize,
    ) -> Option<FaultKind> {
        let spec = self.faults.iter_mut().find(|f| f.matches(tenant, epoch, phase, shard))?;
        spec.fired = true;
        Some(spec.kind)
    }

    /// Parse a plan from the `PERKS_FAULT_PLAN` environment variable.
    /// Returns `Ok(None)` when unset or blank. A malformed value is a
    /// hard [`Error::Config`] naming the offending token: a typo in a
    /// CI matrix must fail the run, not silently execute the workload
    /// with an empty (or partial) plan and report a vacuous pass.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        let Ok(raw) = std::env::var("PERKS_FAULT_PLAN") else {
            return Ok(None);
        };
        if raw.trim().is_empty() {
            return Ok(None);
        }
        Self::parse(&raw)
            .map(Some)
            .map_err(|e| Error::Config(format!("PERKS_FAULT_PLAN rejected: {e}")))
    }

    /// Parse the env-variable syntax: `;`-separated entries of
    /// `kind@key=value,...` where kind is `panic`, `nan`, `stall`
    /// (stall requires `ms=<millis>`) or `kill`, and keys are `epoch`
    /// (required), `tenant`, `phase`, `shard`.
    ///
    /// ```text
    /// PERKS_FAULT_PLAN="panic@epoch=2,phase=1,shard=0;nan@epoch=3,tenant=1"
    /// ```
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new();
        for entry in s.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once('@')
                .ok_or_else(|| Error::Config(format!("fault entry missing '@': {entry:?}")))?;
            let mut epoch = None;
            let mut tenant = None;
            let mut phase = None;
            let mut shard = None;
            let mut ms = None;
            for kv in rest.split(',').map(str::trim).filter(|e| !e.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| Error::Config(format!("fault key missing '=': {kv:?}")))?;
                let parse_u64 = |v: &str| {
                    v.trim()
                        .parse::<u64>()
                        .map_err(|_| Error::Config(format!("bad fault value {v:?} for {k:?}")))
                };
                match k.trim() {
                    "epoch" => epoch = Some(parse_u64(v)?),
                    "tenant" => tenant = Some(parse_u64(v)? as usize),
                    "phase" => phase = Some(parse_u64(v)? as u8),
                    "shard" => shard = Some(parse_u64(v)? as usize),
                    "ms" => ms = Some(parse_u64(v)?),
                    other => {
                        return Err(Error::Config(format!("unknown fault key {other:?}")));
                    }
                }
            }
            let epoch =
                epoch.ok_or_else(|| Error::Config(format!("fault entry needs epoch=: {entry:?}")))?;
            let kind = match kind.trim() {
                "panic" => FaultKind::Panic,
                "nan" => FaultKind::Nan,
                "stall" => FaultKind::Stall(Duration::from_millis(ms.ok_or_else(|| {
                    Error::Config(format!("stall entry needs ms=: {entry:?}"))
                })?)),
                "kill" => FaultKind::Kill,
                other => return Err(Error::Config(format!("unknown fault kind {other:?}"))),
            };
            plan.faults.push(FaultSpec { kind, epoch, tenant, phase, shard, fired: false });
        }
        if plan.is_empty() {
            return Err(Error::Config("fault plan has no entries".into()));
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_kind_and_key() {
        let plan = FaultPlan::parse(
            "panic@epoch=2,phase=1,shard=0; nan@epoch=3,tenant=1; stall@epoch=0,ms=25; kill@epoch=5,tenant=0",
        )
        .unwrap();
        assert_eq!(plan.len(), 4);
        let f = &plan.faults[0];
        assert_eq!(f.kind, FaultKind::Panic);
        assert_eq!((f.epoch, f.phase, f.shard, f.tenant), (2, Some(1), Some(0), None));
        let f = &plan.faults[1];
        assert_eq!(f.kind, FaultKind::Nan);
        assert_eq!((f.epoch, f.tenant), (3, Some(1)));
        assert_eq!(plan.faults[2].kind, FaultKind::Stall(Duration::from_millis(25)));
        let f = &plan.faults[3];
        assert_eq!(f.kind, FaultKind::Kill);
        assert_eq!((f.epoch, f.tenant), (5, Some(0)));
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "panic",                 // no coordinates
            "panic@phase=1",         // missing epoch
            "stall@epoch=1",         // stall without ms
            "meteor@epoch=1",        // unknown kind
            "panic@epoch=x",         // bad number
            "panic@epoch=1,zz=2",    // unknown key
            "panic@epoch",           // key without value
            "",                      // empty plan
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn claim_matches_wildcards_and_fires_once() {
        let mut plan = FaultPlan::new()
            .inject(FaultSpec::panic_at(2).tenant(1).phase(1).shard(0))
            .inject(FaultSpec::nan_at(3));
        // wrong coordinates never fire
        assert!(plan.claim(0, 2, 1, 0).is_none(), "wrong tenant");
        assert!(plan.claim(1, 1, 1, 0).is_none(), "wrong epoch");
        assert!(plan.claim(1, 2, 0, 0).is_none(), "wrong phase");
        assert!(plan.claim(1, 2, 1, 1).is_none(), "wrong shard");
        assert_eq!(plan.pending(), 2);
        // exact match fires exactly once
        assert_eq!(plan.claim(1, 2, 1, 0), Some(FaultKind::Panic));
        assert!(plan.claim(1, 2, 1, 0).is_none(), "specs fire once");
        // wildcard entry matches any tenant/phase/shard at its epoch
        assert_eq!(plan.claim(7, 3, 2, 5), Some(FaultKind::Nan));
        assert_eq!(plan.pending(), 0);
    }

    #[test]
    fn seeded_plans_are_deterministic_and_in_range() {
        let a = FaultPlan::seeded(42, 8, 4);
        let b = FaultPlan::seeded(42, 8, 4);
        assert_eq!(a.faults[0].epoch, b.faults[0].epoch);
        assert_eq!(a.faults[0].shard, b.faults[0].shard);
        assert_eq!(a.faults[0].kind, b.faults[0].kind);
        for seed in 0..64u64 {
            let p = FaultPlan::seeded(seed, 8, 4);
            assert!(p.faults[0].epoch < 8);
            assert!(p.faults[0].shard.unwrap() < 4);
            assert!(matches!(p.faults[0].kind, FaultKind::Panic | FaultKind::Nan));
        }
    }

    #[test]
    fn retry_policy_and_config_defaults_are_disabled() {
        assert_eq!(RetryPolicy::default(), RetryPolicy::disabled());
        assert!(!ResilienceConfig::default().enabled());
        assert!(ResilienceConfig::checkpointed().enabled());
        let cfg = ResilienceConfig::recovering(3);
        assert_eq!(cfg.checkpoint_every, DEFAULT_CHECKPOINT_EVERY);
        assert_eq!(cfg.retry.max_attempts, 3);
        assert!(cfg.enabled());
        let cfg = cfg.every(4).with_deadline(Duration::from_millis(50));
        assert_eq!(cfg.checkpoint_every, 4);
        assert_eq!(cfg.deadline, Some(Duration::from_millis(50)));
    }

    #[test]
    fn durable_knob_arms_the_config_and_composes_with_cadence_zero() {
        let cfg = ResilienceConfig::disabled().durable("/tmp/perks-snap");
        assert!(cfg.enabled(), "a durable dir alone arms the config");
        assert_eq!(cfg.checkpoint_every, 0, "cadence stays off unless set");
        assert_eq!(cfg.durable.as_deref(), Some(std::path::Path::new("/tmp/perks-snap")));
        // kill specs build and claim like any other kind
        let mut plan = FaultPlan::new().inject(FaultSpec::kill_at(4).tenant(2));
        assert!(plan.claim(2, 3, 0, 0).is_none(), "wrong epoch");
        assert_eq!(plan.claim(2, 4, 1, 3), Some(FaultKind::Kill));
        assert_eq!(plan.pending(), 0);
    }

    #[test]
    fn checkpoint_bytes_account_the_payload() {
        let ck = Checkpoint::new(
            5,
            CheckpointPayload::Cg {
                x: vec![0.0; 10],
                r: vec![0.0; 10],
                p: vec![0.0; 10],
                rr: 1.0,
                iters_done: 5,
                iters_target: 20,
                segs: Vec::new(),
                resubmits: 0,
            },
        );
        assert_eq!(ck.epoch, 5);
        assert_eq!(ck.bytes, 240);
        let ck = Checkpoint::new(
            2,
            CheckpointPayload::Stencil {
                grid: vec![0.0; 100],
                slabs: vec![(vec![0.0; 20], vec![0.0; 20]); 2],
                done_steps: 2,
                residual: None,
                loaded: true,
                moved: 0,
                computed: 0,
                steps_target: 8,
                segs: vec![2, 2],
                resubmits: 0,
            },
        );
        assert_eq!(ck.bytes, (100 + 80) * 8);
    }
}
