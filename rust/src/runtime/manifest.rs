//! Artifact manifest: the contract between the python AOT compile path and
//! the rust runtime.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` with one line per
//! artifact, each line a space-separated list of `key=value` pairs. The
//! required keys are `name`, `kind`, `in`, `out`, `tuple`; solver-specific
//! keys (`bench`, `interior`, `steps`, `n`, `nnz`, ...) ride along in
//! `params`. Signatures look like `f32[130,130],i32[4992]`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Element type of a tensor in an artifact signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "f64" => Ok(DType::F64),
            "i32" => Ok(DType::I32),
            other => Err(Error::Manifest(format!("unknown dtype {other:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
        }
    }
}

/// Shape + dtype of one tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn new(dtype: DType, dims: &[usize]) -> Self {
        Self { dtype, dims: dims.to_vec() }
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    /// Parse a single `f32[130,130]` item.
    fn parse_one(s: &str) -> Result<Self> {
        let open = s.find('[').ok_or_else(|| Error::Manifest(format!("bad spec {s:?}")))?;
        if !s.ends_with(']') {
            return Err(Error::Manifest(format!("bad spec {s:?}")));
        }
        let dtype = DType::parse(&s[..open])?;
        let inner = &s[open + 1..s.len() - 1];
        let dims = if inner.is_empty() {
            vec![]
        } else {
            inner
                .split(',')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|_| Error::Manifest(format!("bad dim {d:?} in {s:?}")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Self { dtype, dims })
    }

    /// Parse a comma-separated signature like `f32[3,4],i32[7]`.
    ///
    /// Commas appear both between specs and inside brackets, so split on
    /// `],` boundaries.
    pub fn parse_sig(sig: &str) -> Result<Vec<Self>> {
        if sig.is_empty() {
            return Ok(vec![]);
        }
        let mut specs = Vec::new();
        let mut rest = sig;
        loop {
            match rest.find(']') {
                None => return Err(Error::Manifest(format!("unterminated spec in {sig:?}"))),
                Some(end) => {
                    specs.push(Self::parse_one(&rest[..=end])?);
                    if end + 1 >= rest.len() {
                        break;
                    }
                    if &rest[end + 1..end + 2] != "," {
                        return Err(Error::Manifest(format!("bad separator in {sig:?}")));
                    }
                    rest = &rest[end + 2..];
                }
            }
        }
        Ok(specs)
    }
}

impl std::fmt::Display for TensorSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "{}[{}]", self.dtype.name(), dims.join(","))
    }
}

/// One artifact as described by the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    /// Path of the `.hlo.txt` file (resolved against the manifest dir).
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Whether the HLO root is a tuple (lowered with return_tuple=True).
    pub tupled: bool,
    /// Solver-specific key/values (bench, interior, steps, n, nnz, ...).
    pub params: HashMap<String, String>,
}

impl ArtifactMeta {
    /// Integer parameter accessor, e.g. `steps`, `n`, `nnz`, `radius`.
    pub fn int(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| Error::Manifest(format!("{}: missing int param {key:?}", self.name)))
    }

    /// String parameter accessor.
    pub fn str(&self, key: &str) -> Result<&str> {
        self.params
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::Manifest(format!("{}: missing param {key:?}", self.name)))
    }

    fn parse_line(line: &str, dir: &Path) -> Result<Self> {
        let mut kv = HashMap::new();
        for part in line.split_whitespace() {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| Error::Manifest(format!("bad pair {part:?}")))?;
            kv.insert(k.to_string(), v.to_string());
        }
        let take = |kv: &mut HashMap<String, String>, k: &str| -> Result<String> {
            kv.remove(k).ok_or_else(|| Error::Manifest(format!("missing key {k:?} in {line:?}")))
        };
        let name = take(&mut kv, "name")?;
        let kind = take(&mut kv, "kind")?;
        let inputs = TensorSpec::parse_sig(&take(&mut kv, "in")?)?;
        let outputs = TensorSpec::parse_sig(&take(&mut kv, "out")?)?;
        let tupled = take(&mut kv, "tuple")? == "1";
        let path = dir.join(format!("{name}.hlo.txt"));
        Ok(Self { name, kind, path, inputs, outputs, tupled, params: kv })
    }
}

/// Parsed manifest: ordered artifact list + name index.
#[derive(Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            artifacts.push(ArtifactMeta::parse_line(line, dir)?);
        }
        Ok(Self { artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Manifest(format!("no artifact named {name:?}")))
    }

    /// All artifacts of a given kind (e.g. "stencil_perks").
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sig_multi() {
        let specs = TensorSpec::parse_sig("f32[3,4],i32[7],f64[1]").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0], TensorSpec::new(DType::F32, &[3, 4]));
        assert_eq!(specs[1], TensorSpec::new(DType::I32, &[7]));
        assert_eq!(specs[2], TensorSpec::new(DType::F64, &[1]));
    }

    #[test]
    fn parse_sig_roundtrip_display() {
        let s = "f32[130,130]";
        let spec = &TensorSpec::parse_sig(s).unwrap()[0];
        assert_eq!(spec.to_string(), s);
    }

    #[test]
    fn parse_sig_rejects_garbage() {
        assert!(TensorSpec::parse_sig("f32[3,4").is_err());
        assert!(TensorSpec::parse_sig("u8[3]").is_err());
        assert!(TensorSpec::parse_sig("f32[x]").is_err());
    }

    #[test]
    fn spec_bytes() {
        let spec = TensorSpec::new(DType::F64, &[10, 10]);
        assert_eq!(spec.elements(), 100);
        assert_eq!(spec.bytes(), 800);
    }

    #[test]
    fn parse_manifest_line() {
        let text = "name=a kind=stencil_step in=f32[10,10] out=f32[10,10] tuple=1 bench=2d5pt steps=1\n\
                    # comment\n\
                    name=b kind=cg_step in=f32[8],i32[8] out=f32[8] tuple=0 n=8 nnz=8\n";
        let m = Manifest::parse(text, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("a").unwrap();
        assert!(a.tupled);
        assert_eq!(a.str("bench").unwrap(), "2d5pt");
        assert_eq!(a.int("steps").unwrap(), 1);
        let b = m.get("b").unwrap();
        assert!(!b.tupled);
        assert_eq!(b.int("nnz").unwrap(), 8);
        assert_eq!(b.path, Path::new("/tmp/a/b.hlo.txt"));
        assert!(m.get("missing").is_err());
    }

    #[test]
    fn by_kind_filters() {
        let text = "name=a kind=x in=f32[1] out=f32[1] tuple=1\n\
                    name=b kind=y in=f32[1] out=f32[1] tuple=1\n\
                    name=c kind=x in=f32[1] out=f32[1] tuple=1\n";
        let m = Manifest::parse(text, Path::new(".")).unwrap();
        assert_eq!(m.by_kind("x").len(), 2);
        assert_eq!(m.by_kind("z").len(), 0);
    }
}
