//! Runtime layer: loads AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`) and executes them on the PJRT CPU client.
//! Python is never on this path.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, Runtime, RuntimeMetrics};
pub use manifest::{ArtifactMeta, DType, Manifest, TensorSpec};
pub use tensor::HostTensor;
