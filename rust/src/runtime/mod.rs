//! Runtime layer: the execution substrates sessions run on, split into a
//! back-end (where shards compute), a front-end (how commands get in and
//! results get out), and a supervision layer (what happens when a shard
//! fails).
//!
//! * [`client`]/[`manifest`]/[`tensor`] — load AOT-compiled HLO artifacts
//!   (produced once by `python/compile/aot.py`) and execute them on the
//!   PJRT CPU client. Python is never on this path.
//! * [`farm`] — the back-end: the multi-tenant [`farm::SolverFarm`], one
//!   spawn-once worker pool executing many concurrent stencil/CG
//!   sessions via phase-sharded commands and countdown transitions (see
//!   `SessionBuilder::farm`).
//! * [`plane`] — the front-end: the async submission plane every farm
//!   command passes through. Completion futures driven by a
//!   dependency-free reactor + [`plane::LocalExecutor`] (one OS thread
//!   multiplexes thousands of in-flight sessions; the blocking
//!   `wait`/`advance`/`run` wrappers are [`plane::block_on`] over the
//!   same futures), batched [`plane::CommandGraph`]s that enqueue an
//!   entire `advance_until` schedule under a single scheduler-lock
//!   acquisition, and bounded admission control with block/shed/timeout
//!   backpressure ([`plane::PlaneConfig`], `SolverFarm::spawn_with`).
//! * [`resilience`] — the supervision layer: epoch-boundary
//!   checkpointing of resident tenant state (a cheap copy under the
//!   scheduler lock the completion transition already holds), seeded
//!   deterministic fault injection ([`resilience::FaultPlan`]: panics,
//!   NaN poisoning, stalls at exact tenant/epoch/phase/shard
//!   coordinates, replayable from the `PERKS_FAULT_PLAN` environment
//!   variable), and supervised recovery ([`resilience::RetryPolicy`]:
//!   checkpoint-restore + bit-identical replay instead of a command
//!   error, with a watchdog deadline for stuck commands). Its
//!   [`resilience::snapshot`] submodule extends recovery past the
//!   process boundary: crash-consistent, checksummed, generation-
//!   numbered persistence of the same checkpoints
//!   ([`resilience::snapshot::SnapshotStore`]), so a killed process
//!   resumes bit-identical via the `perks_recover` binary.
//!
//! The split mirrors the paper's host/device boundary: the farm is the
//! persistent "device" (resident workers, resident tenant state), the
//! plane is the launch path whose per-command host cost the batching
//! collapses, and the resilience layer is what makes long-resident state
//! survivable — the blast radius of keeping hours of progress resident
//! is a panic away from a full re-solve without it. None of the three
//! ever changes what a shard computes, so the farm's bit-identity
//! guarantees survive every front-end mode *and* every recovery replay
//! (which is exactly what makes recovery checkable).

pub mod client;
pub mod farm;
pub mod manifest;
pub mod plane;
pub mod resilience;
pub mod tensor;

pub use client::{Executable, Runtime, RuntimeMetrics};
pub use farm::{FarmHandle, FarmMetrics, SolverFarm};
pub use manifest::{ArtifactMeta, DType, Manifest, TensorSpec};
pub use plane::{
    block_on, AdmissionPolicy, CommandGraph, CommandGraphBuilder, LocalExecutor, PlaneConfig,
};
pub use resilience::snapshot::{Restored, SnapshotStore, WorkloadMeta};
pub use resilience::{
    Checkpoint, FaultKind, FaultPlan, FaultSpec, ResilienceConfig, RetryPolicy,
    DEFAULT_CHECKPOINT_EVERY,
};
pub use tensor::HostTensor;
