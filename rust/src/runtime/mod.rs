//! Runtime layer: the execution substrates sessions run on.
//!
//! * [`client`]/[`manifest`]/[`tensor`] — load AOT-compiled HLO artifacts
//!   (produced once by `python/compile/aot.py`) and execute them on the
//!   PJRT CPU client. Python is never on this path.
//! * [`farm`] — the multi-tenant [`farm::SolverFarm`] serving path: one
//!   spawn-once worker pool executing many concurrent stencil/CG sessions
//!   (see `SessionBuilder::farm`).

pub mod client;
pub mod farm;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, Runtime, RuntimeMetrics};
pub use farm::{FarmHandle, FarmMetrics, SolverFarm};
pub use manifest::{ArtifactMeta, DType, Manifest, TensorSpec};
pub use tensor::HostTensor;
