//! Runtime layer: the execution substrates sessions run on, split into a
//! back-end (where shards compute) and a front-end (how commands get in
//! and results get out).
//!
//! * [`client`]/[`manifest`]/[`tensor`] — load AOT-compiled HLO artifacts
//!   (produced once by `python/compile/aot.py`) and execute them on the
//!   PJRT CPU client. Python is never on this path.
//! * [`farm`] — the back-end: the multi-tenant [`farm::SolverFarm`], one
//!   spawn-once worker pool executing many concurrent stencil/CG
//!   sessions via phase-sharded commands and countdown transitions (see
//!   `SessionBuilder::farm`).
//! * [`plane`] — the front-end: the async submission plane every farm
//!   command passes through. Completion futures driven by a
//!   dependency-free reactor + [`plane::LocalExecutor`] (one OS thread
//!   multiplexes thousands of in-flight sessions; the blocking
//!   `wait`/`advance`/`run` wrappers are [`plane::block_on`] over the
//!   same futures), batched [`plane::CommandGraph`]s that enqueue an
//!   entire `advance_until` schedule under a single scheduler-lock
//!   acquisition, and bounded admission control with block/shed/timeout
//!   backpressure ([`plane::PlaneConfig`], `SolverFarm::spawn_with`).
//!
//! The split mirrors the paper's host/device boundary: the farm is the
//! persistent "device" (resident workers, resident tenant state), the
//! plane is the launch path whose per-command host cost the batching
//! collapses — and neither side ever changes what a shard computes, so
//! the farm's bit-identity guarantees survive every front-end mode.

pub mod client;
pub mod farm;
pub mod manifest;
pub mod plane;
pub mod tensor;

pub use client::{Executable, Runtime, RuntimeMetrics};
pub use farm::{FarmHandle, FarmMetrics, SolverFarm};
pub use manifest::{ArtifactMeta, DType, Manifest, TensorSpec};
pub use plane::{
    block_on, AdmissionPolicy, CommandGraph, CommandGraphBuilder, LocalExecutor, PlaneConfig,
};
pub use tensor::HostTensor;
