//! Durable, crash-consistent persistence for [`Checkpoint`]s: the layer
//! that lets resident solver state outlive the hosting process.
//!
//! # On-disk layout
//!
//! ```text
//! <root>/                        # SnapshotStore::open(root)
//!   <tenant>/                    # one directory per tenant name
//!     MANIFEST                   # versioned, checksummed entry list
//!     gen-7.frame                # generation-numbered frames
//!     gen-8.frame
//! ```
//!
//! A **frame** is a 24-byte header (magic, format version, body length,
//! FNV-1a 64 body checksum) followed by the body: the tenant's
//! [`WorkloadMeta`] (how to rebuild the tenant) plus the full
//! [`Checkpoint`] payload, serialized by the dependency-free
//! [`crate::util::codec`] — floats travel as raw IEEE-754 bit patterns,
//! never through text, so a restored frame is **bit-identical** to the
//! in-memory checkpoint it came from.
//!
//! The **manifest** lists the generations that are *committed*: per
//! entry the generation number, epoch, frame length, and frame
//! checksum, with its own trailing checksum over the whole encoding.
//!
//! # Crash-consistency argument
//!
//! Every file write goes through the same protocol: write `*.tmp`,
//! `fsync` the file, atomically `rename` into place, then best-effort
//! `fsync` the directory. A frame counts as committed **only once a
//! manifest naming it has been renamed into place** — and the frame is
//! always durable before that manifest write starts. So at every crash
//! point the directory is recoverable:
//!
//! * crash mid-frame-write → a stale `*.tmp`; the manifest still names
//!   only older, fully-durable frames. The leftover is ignored by
//!   restore and deleted by the next persist.
//! * crash after the frame rename but before the manifest rename → an
//!   unmanifested `gen-N.frame`; restore never reads it (it walks the
//!   manifest, not the directory), so the previous generation wins.
//! * crash mid-manifest-write → the old manifest is intact (rename is
//!   atomic); same as above.
//! * bit rot / torn sectors after commit → the per-frame checksum (and
//!   the manifest's own) fail verification and restore falls back one
//!   generation; only when *no* generation verifies does a structured
//!   [`Error::Snapshot`] surface. Restore never panics on corrupt bytes.
//!
//! Old generations are pruned only after the manifest that drops them
//! is durable, keeping [`SnapshotStore::with_keep`] generations as
//! fallback depth. Write-outs are counter-tracked via
//! [`crate::util::counters::durable_frames`] / `durable_bytes`, and
//! verified restores via `restores`. See `docs/RECOVERY.md` for the
//! full format walkthrough and recovery procedure.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::codec::{fnv1a64, Decoder, Encoder};
use crate::util::counters;

use super::{Checkpoint, CheckpointPayload};

/// Frame file magic: `PKSF` little-endian.
const FRAME_MAGIC: u32 = 0x504b_5346;
/// Manifest file magic: `PKSM` little-endian.
const MANIFEST_MAGIC: u32 = 0x504b_534d;
/// On-disk format version; bump on any layout change so old readers
/// reject new frames loudly instead of misdecoding them.
const FORMAT_VERSION: u32 = 1;
/// Frame header length: magic + version + body length + body checksum.
const HEADER_LEN: usize = 4 + 4 + 8 + 8;
/// Manifest file name inside a tenant directory.
const MANIFEST: &str = "MANIFEST";
/// Default fallback depth: the committed generation plus one older one.
pub const DEFAULT_KEEP: usize = 2;

const TAG_STENCIL: u8 = 0;
const TAG_CG: u8 = 1;

/// Everything a fresh process needs to rebuild the tenant a frame
/// belongs to, persisted alongside the checkpoint so a snapshot
/// directory is self-describing (`perks_recover` resumes from the
/// directory alone, no out-of-band config).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkloadMeta {
    /// A farm stencil tenant: benchmark name (`2d5pt`, `3d7pt`, ...),
    /// grid dimensions, temporal-block depth, and shard count.
    Stencil {
        bench: String,
        dims: Vec<usize>,
        bt: usize,
        shards: usize,
    },
    /// A farm CG tenant: system size and shard count. The matrix itself
    /// is rebuilt by the resuming client (the demo workloads use the
    /// Poisson operators, which are fully determined by `n`).
    Cg { n: usize, shards: usize },
}

impl WorkloadMeta {
    /// One-line human description for `perks_recover list`.
    pub fn describe(&self) -> String {
        match self {
            WorkloadMeta::Stencil { bench, dims, bt, shards } => {
                let dims: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
                format!("stencil {bench} {} bt={bt} shards={shards}", dims.join("x"))
            }
            WorkloadMeta::Cg { n, shards } => format!("cg n={n} shards={shards}"),
        }
    }
}

/// One committed generation, as recorded in a tenant's manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Monotonic generation number (never reused within a directory).
    pub generation: u64,
    /// Tenant lifetime epoch the frame's checkpoint was taken at.
    pub epoch: u64,
    /// Expected frame file length in bytes (header + body).
    pub frame_len: u64,
    /// FNV-1a 64 checksum of the frame body, duplicated here so a
    /// frame/manifest mismatch is detectable from either side.
    pub checksum: u64,
}

/// Verification outcome for one manifested generation
/// ([`SnapshotStore::verify`]).
#[derive(Clone, Debug)]
pub struct FrameStatus {
    pub generation: u64,
    pub epoch: u64,
    /// `None` when the frame verified end-to-end; otherwise what failed.
    pub problem: Option<String>,
}

/// A successful restore: which generation survived verification and how
/// many newer ones had to be skipped to reach it.
#[derive(Debug)]
pub struct Restored {
    pub generation: u64,
    /// Newer manifested generations that failed verification (torn or
    /// corrupt) before this one verified. 0 on a clean directory.
    pub fallbacks: u64,
    pub meta: WorkloadMeta,
    pub checkpoint: Checkpoint,
}

/// Crash-consistent, generation-numbered checkpoint persistence rooted
/// at one directory. Cheap to construct (two words); all state lives on
/// disk, so any number of stores — in any number of processes — may
/// point at the same root, as long as at most one writes per tenant.
#[derive(Clone, Debug)]
pub struct SnapshotStore {
    root: PathBuf,
    keep: usize,
}

impl SnapshotStore {
    /// Open (creating if needed) a snapshot root directory.
    pub fn open(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        fs::create_dir_all(&root)?;
        Ok(Self { root, keep: DEFAULT_KEEP })
    }

    /// Retain this many committed generations per tenant (minimum 1).
    /// More generations mean deeper fallback at more disk.
    pub fn with_keep(mut self, keep: usize) -> Self {
        self.keep = keep.max(1);
        self
    }

    /// The directory this store reads and writes.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Persist one checkpoint as the next generation for `tenant`,
    /// crash-consistently (see the module docs for the protocol), and
    /// prune generations beyond the retention depth. Returns the
    /// committed generation number.
    pub fn persist(&self, tenant: &str, meta: &WorkloadMeta, ck: &Checkpoint) -> Result<u64> {
        let dir = self.tenant_dir(tenant)?;
        fs::create_dir_all(&dir)?;
        // A corrupt manifest forfeits its fallback chain (we cannot
        // trust what it names) but never blocks new progress: start a
        // fresh chain above every generation number ever used.
        let mut entries = self.read_manifest(&dir).unwrap_or_default();
        let last_listed = entries.last().map_or(0, |e| e.generation);
        let generation = scan_max_generation(&dir).max(last_listed) + 1;

        let body = encode_body(meta, ck);
        let checksum = fnv1a64(&body);
        let mut framed = Encoder::with_capacity(HEADER_LEN + body.len());
        framed.put_u32(FRAME_MAGIC);
        framed.put_u32(FORMAT_VERSION);
        framed.put_u64(body.len() as u64);
        framed.put_u64(checksum);
        let mut frame = framed.finish();
        frame.extend_from_slice(&body);
        let frame_len = frame.len() as u64;

        // Frame first: it must be durable before any manifest names it.
        write_atomic(&dir, &frame_name(generation), &frame)?;
        entries.push(ManifestEntry { generation, epoch: ck.epoch, frame_len, checksum });
        if entries.len() > self.keep {
            let drop = entries.len() - self.keep;
            entries.drain(..drop);
        }
        write_atomic(&dir, MANIFEST, &encode_manifest(&entries))?;
        // Only after the new manifest is durable is it safe to delete
        // what it no longer names (plus any stale tmp from a dead
        // writer). Best-effort: a leftover file is ignored by restore.
        prune(&dir, &entries);

        counters::note_durable_frames(1);
        counters::note_durable_bytes(frame_len);
        Ok(generation)
    }

    /// Restore the newest generation of `tenant` that verifies
    /// end-to-end, falling back one generation at a time past torn or
    /// corrupt frames. Structured [`Error::Snapshot`] when no manifested
    /// generation survives — never a panic, never bad bits.
    pub fn restore(&self, tenant: &str) -> Result<Restored> {
        let dir = self.tenant_dir(tenant)?;
        let entries = self.read_manifest(&dir)?;
        let mut problems: Vec<String> = Vec::new();
        for entry in entries.iter().rev() {
            match check_frame(&dir, entry) {
                Ok((meta, checkpoint)) => {
                    counters::note_restores(1);
                    return Ok(Restored {
                        generation: entry.generation,
                        fallbacks: problems.len() as u64,
                        meta,
                        checkpoint,
                    });
                }
                Err(e) => problems.push(format!("gen {}: {e}", entry.generation)),
            }
        }
        if problems.is_empty() {
            return Err(Error::Snapshot(format!(
                "tenant {tenant:?}: manifest lists no generations"
            )));
        }
        Err(Error::Snapshot(format!(
            "tenant {tenant:?}: no generation verified ({})",
            problems.join("; ")
        )))
    }

    /// The committed generations of `tenant`, oldest first, straight
    /// from the manifest (no frame verification — see [`Self::verify`]).
    pub fn entries(&self, tenant: &str) -> Result<Vec<ManifestEntry>> {
        let dir = self.tenant_dir(tenant)?;
        self.read_manifest(&dir)
    }

    /// Verify every manifested generation of `tenant`: header, length,
    /// checksum, and full payload decode. Read-only.
    pub fn verify(&self, tenant: &str) -> Result<Vec<FrameStatus>> {
        let dir = self.tenant_dir(tenant)?;
        let entries = self.read_manifest(&dir)?;
        Ok(entries
            .iter()
            .map(|entry| FrameStatus {
                generation: entry.generation,
                epoch: entry.epoch,
                problem: check_frame(&dir, entry).err().map(|e| e.to_string()),
            })
            .collect())
    }

    /// Tenant names with a manifest under this root, sorted.
    pub fn tenants(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for e in fs::read_dir(&self.root)? {
            let e = e?;
            let name_os = e.file_name();
            let Some(name) = name_os.to_str() else { continue };
            if e.path().join(MANIFEST).is_file() {
                out.push(name.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    fn tenant_dir(&self, tenant: &str) -> Result<PathBuf> {
        let ok = !tenant.is_empty()
            && tenant.len() <= 64
            && !tenant.starts_with('.')
            && tenant
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
        if !ok {
            // Tenant names become path components; anything outside the
            // safe alphabet (separators, leading dots, ..) is rejected
            // rather than sanitized so two names can never collide.
            return Err(Error::Snapshot(format!(
                "invalid tenant name {tenant:?}: need 1-64 chars of [A-Za-z0-9._-], no leading dot"
            )));
        }
        Ok(self.root.join(tenant))
    }

    fn read_manifest(&self, dir: &Path) -> Result<Vec<ManifestEntry>> {
        let path = dir.join(MANIFEST);
        let bytes = fs::read(&path)
            .map_err(|e| Error::Snapshot(format!("no readable manifest at {}: {e}", path.display())))?;
        decode_manifest(&bytes)
            .map_err(|e| Error::Snapshot(format!("corrupt manifest at {}: {e}", path.display())))
    }
}

fn frame_name(generation: u64) -> String {
    format!("gen-{generation}.frame")
}

/// The write protocol every snapshot file uses: tmp + fsync + atomic
/// rename + best-effort directory fsync. After `Ok`, the bytes are
/// durable under `name` or the old content is untouched — never a mix.
fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    let fin = dir.join(name);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, &fin)?;
    // The rename itself is only durable once the directory inode is
    // synced; some filesystems refuse directory fsync, hence best-effort
    // (on those, the OS orders the metadata itself).
    if let Ok(d) = fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Highest generation number present as a frame *file* (manifested or
/// not) — new generations allocate above this so an unmanifested
/// leftover from a crashed writer is never overwritten in place.
fn scan_max_generation(dir: &Path) -> u64 {
    let Ok(rd) = fs::read_dir(dir) else { return 0 };
    let mut max = 0;
    for e in rd.flatten() {
        let name_os = e.file_name();
        let Some(name) = name_os.to_str() else { continue };
        if let Some(num) = name.strip_prefix("gen-").and_then(|s| s.strip_suffix(".frame")) {
            if let Ok(g) = num.parse::<u64>() {
                max = max.max(g);
            }
        }
    }
    max
}

/// Delete frame files the durable manifest no longer names, and any
/// stale `*.tmp` from a writer that died mid-protocol. Best-effort by
/// design: a file that refuses deletion is simply ignored by restore.
fn prune(dir: &Path, entries: &[ManifestEntry]) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    for e in rd.flatten() {
        let name_os = e.file_name();
        let Some(name) = name_os.to_str() else { continue };
        let retain = if name == MANIFEST {
            true
        } else if name.ends_with(".tmp") {
            false
        } else if let Some(num) = name.strip_prefix("gen-").and_then(|s| s.strip_suffix(".frame")) {
            num.parse::<u64>().map_or(false, |g| entries.iter().any(|en| en.generation == g))
        } else {
            // unknown files are someone else's; leave them alone
            true
        };
        if !retain {
            let _ = fs::remove_file(e.path());
        }
    }
}

fn encode_manifest(entries: &[ManifestEntry]) -> Vec<u8> {
    let mut e = Encoder::with_capacity(16 + entries.len() * 32 + 8);
    e.put_u32(MANIFEST_MAGIC);
    e.put_u32(FORMAT_VERSION);
    e.put_usize(entries.len());
    for en in entries {
        e.put_u64(en.generation);
        e.put_u64(en.epoch);
        e.put_u64(en.frame_len);
        e.put_u64(en.checksum);
    }
    let mut bytes = e.finish();
    let sum = fnv1a64(&bytes);
    bytes.extend_from_slice(&sum.to_le_bytes());
    bytes
}

fn decode_manifest(bytes: &[u8]) -> Result<Vec<ManifestEntry>> {
    if bytes.len() < 8 {
        return Err(Error::Snapshot(format!("manifest truncated to {} bytes", bytes.len())));
    }
    let (content, tail) = bytes.split_at(bytes.len() - 8);
    let mut t = Decoder::new(tail);
    if t.take_u64("manifest checksum")? != fnv1a64(content) {
        return Err(Error::Snapshot("manifest checksum mismatch".into()));
    }
    let mut d = Decoder::new(content);
    if d.take_u32("manifest magic")? != MANIFEST_MAGIC {
        return Err(Error::Snapshot("bad manifest magic".into()));
    }
    let version = d.take_u32("manifest version")?;
    if version != FORMAT_VERSION {
        return Err(Error::Snapshot(format!(
            "manifest format version {version} (this build reads {FORMAT_VERSION})"
        )));
    }
    let count = d.take_usize("manifest entry count")?;
    let need = count.checked_mul(32).ok_or_else(|| {
        Error::Snapshot(format!("manifest entry count {count} overflows the byte count"))
    })?;
    if d.remaining() < need {
        return Err(Error::Snapshot(format!(
            "manifest truncated: {count} entries need {need} bytes, {} remain",
            d.remaining()
        )));
    }
    let mut entries = Vec::with_capacity(count);
    for _ in 0..count {
        entries.push(ManifestEntry {
            generation: d.take_u64("manifest generation")?,
            epoch: d.take_u64("manifest epoch")?,
            frame_len: d.take_u64("manifest frame length")?,
            checksum: d.take_u64("manifest frame checksum")?,
        });
    }
    if !d.is_empty() {
        return Err(Error::Snapshot(format!(
            "manifest has {} trailing bytes past its entries",
            d.remaining()
        )));
    }
    Ok(entries)
}

/// Read, header-check, checksum, and fully decode one manifested frame.
fn check_frame(dir: &Path, entry: &ManifestEntry) -> Result<(WorkloadMeta, Checkpoint)> {
    let path = dir.join(frame_name(entry.generation));
    let bytes = fs::read(&path)
        .map_err(|e| Error::Snapshot(format!("unreadable frame {}: {e}", path.display())))?;
    if bytes.len() as u64 != entry.frame_len {
        return Err(Error::Snapshot(format!(
            "torn frame: {} bytes on disk, manifest says {}",
            bytes.len(),
            entry.frame_len
        )));
    }
    let mut d = Decoder::new(&bytes);
    if d.take_u32("frame magic")? != FRAME_MAGIC {
        return Err(Error::Snapshot("bad frame magic".into()));
    }
    let version = d.take_u32("frame version")?;
    if version != FORMAT_VERSION {
        return Err(Error::Snapshot(format!(
            "frame format version {version} (this build reads {FORMAT_VERSION})"
        )));
    }
    let body_len = d.take_u64("frame body length")?;
    let checksum = d.take_u64("frame checksum")?;
    if checksum != entry.checksum {
        return Err(Error::Snapshot("frame header checksum disagrees with manifest".into()));
    }
    let body = &bytes[HEADER_LEN..];
    if body.len() as u64 != body_len {
        return Err(Error::Snapshot(format!(
            "torn frame body: {} bytes after header, header says {body_len}",
            body.len()
        )));
    }
    if fnv1a64(body) != checksum {
        return Err(Error::Snapshot("frame body checksum mismatch".into()));
    }
    let (meta, checkpoint) = decode_body(body)?;
    if checkpoint.epoch != entry.epoch {
        return Err(Error::Snapshot(format!(
            "frame epoch {} disagrees with manifest epoch {}",
            checkpoint.epoch, entry.epoch
        )));
    }
    Ok((meta, checkpoint))
}

fn encode_body(meta: &WorkloadMeta, ck: &Checkpoint) -> Vec<u8> {
    let mut e = Encoder::with_capacity(ck.bytes as usize + 256);
    match meta {
        WorkloadMeta::Stencil { bench, dims, bt, shards } => {
            e.put_u8(TAG_STENCIL);
            e.put_str(bench);
            e.put_usizes(dims);
            e.put_usize(*bt);
            e.put_usize(*shards);
        }
        WorkloadMeta::Cg { n, shards } => {
            e.put_u8(TAG_CG);
            e.put_usize(*n);
            e.put_usize(*shards);
        }
    }
    e.put_u64(ck.epoch);
    match &ck.payload {
        CheckpointPayload::Stencil {
            grid,
            slabs,
            done_steps,
            residual,
            loaded,
            moved,
            computed,
            steps_target,
            segs,
            resubmits,
        } => {
            e.put_u8(TAG_STENCIL);
            e.put_f64s(grid);
            e.put_usize(slabs.len());
            for (cur, nxt) in slabs {
                e.put_f64s(cur);
                e.put_f64s(nxt);
            }
            e.put_usize(*done_steps);
            e.put_bool(residual.is_some());
            if let Some(r) = residual {
                e.put_f64(*r);
            }
            e.put_bool(*loaded);
            e.put_u64(*moved);
            e.put_u64(*computed);
            e.put_usize(*steps_target);
            e.put_usizes(segs);
            e.put_u32(*resubmits);
        }
        CheckpointPayload::Cg { x, r, p, rr, iters_done, iters_target, segs, resubmits } => {
            e.put_u8(TAG_CG);
            e.put_f64s(x);
            e.put_f64s(r);
            e.put_f64s(p);
            e.put_f64(*rr);
            e.put_usize(*iters_done);
            e.put_usize(*iters_target);
            e.put_usizes(segs);
            e.put_u32(*resubmits);
        }
    }
    e.finish()
}

fn decode_body(body: &[u8]) -> Result<(WorkloadMeta, Checkpoint)> {
    let mut d = Decoder::new(body);
    let meta = match d.take_u8("workload tag")? {
        TAG_STENCIL => WorkloadMeta::Stencil {
            bench: d.take_str("workload bench")?,
            dims: d.take_usizes("workload dims")?,
            bt: d.take_usize("workload bt")?,
            shards: d.take_usize("workload shards")?,
        },
        TAG_CG => WorkloadMeta::Cg {
            n: d.take_usize("workload n")?,
            shards: d.take_usize("workload shards")?,
        },
        t => return Err(Error::Snapshot(format!("unknown workload tag {t:#04x}"))),
    };
    let epoch = d.take_u64("checkpoint epoch")?;
    let payload = match d.take_u8("payload tag")? {
        TAG_STENCIL => {
            let grid = d.take_f64s("stencil grid")?;
            let n_slabs = d.take_usize("stencil slab count")?;
            // each slab is at least two 8-byte length prefixes: guard
            // the count against the remaining bytes before allocating
            let floor = n_slabs.checked_mul(16).ok_or_else(|| {
                Error::Snapshot(format!("slab count {n_slabs} overflows the byte count"))
            })?;
            if d.remaining() < floor {
                return Err(Error::Snapshot(format!(
                    "truncated slabs: count {n_slabs} needs at least {floor} bytes, {} remain",
                    d.remaining()
                )));
            }
            let mut slabs = Vec::with_capacity(n_slabs);
            for _ in 0..n_slabs {
                let cur = d.take_f64s("stencil slab cur")?;
                let nxt = d.take_f64s("stencil slab nxt")?;
                slabs.push((cur, nxt));
            }
            let done_steps = d.take_usize("stencil done_steps")?;
            let residual = if d.take_bool("stencil residual flag")? {
                Some(d.take_f64("stencil residual")?)
            } else {
                None
            };
            CheckpointPayload::Stencil {
                grid,
                slabs,
                done_steps,
                residual,
                loaded: d.take_bool("stencil loaded")?,
                moved: d.take_u64("stencil moved")?,
                computed: d.take_u64("stencil computed")?,
                steps_target: d.take_usize("stencil steps_target")?,
                segs: d.take_usizes("stencil segs")?,
                resubmits: d.take_u32("stencil resubmits")?,
            }
        }
        TAG_CG => CheckpointPayload::Cg {
            x: d.take_f64s("cg x")?,
            r: d.take_f64s("cg r")?,
            p: d.take_f64s("cg p")?,
            rr: d.take_f64("cg rr")?,
            iters_done: d.take_usize("cg iters_done")?,
            iters_target: d.take_usize("cg iters_target")?,
            segs: d.take_usizes("cg segs")?,
            resubmits: d.take_u32("cg resubmits")?,
        },
        t => return Err(Error::Snapshot(format!("unknown payload tag {t:#04x}"))),
    };
    if !d.is_empty() {
        return Err(Error::Snapshot(format!(
            "frame body has {} trailing bytes past the payload",
            d.remaining()
        )));
    }
    let meta_is_stencil = matches!(meta, WorkloadMeta::Stencil { .. });
    let payload_is_stencil = matches!(payload, CheckpointPayload::Stencil { .. });
    if meta_is_stencil != payload_is_stencil {
        return Err(Error::Snapshot("workload meta and payload disagree on engine kind".into()));
    }
    Ok((meta, Checkpoint::new(epoch, payload)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_root(test: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("perks-snapstore-{test}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn stencil_ck(epoch: u64, seed: f64) -> (WorkloadMeta, Checkpoint) {
        let meta = WorkloadMeta::Stencil {
            bench: "2d5pt".into(),
            dims: vec![8, 8],
            bt: 2,
            shards: 3,
        };
        let grid: Vec<f64> = (0..64).map(|i| seed + i as f64 * 0.125).collect();
        let ck = Checkpoint::new(
            epoch,
            CheckpointPayload::Stencil {
                grid,
                slabs: vec![(vec![seed; 16], vec![-seed; 16]), (vec![0.0; 16], vec![1.0; 16])],
                done_steps: 4,
                residual: Some(f64::from_bits(0x7ff8_0000_0000_0001)), // NaN payload survives
                loaded: true,
                moved: 1234,
                computed: 5678,
                steps_target: 8,
                segs: vec![2, 2],
                resubmits: 1,
            },
        );
        (meta, ck)
    }

    fn cg_ck(epoch: u64) -> (WorkloadMeta, Checkpoint) {
        let meta = WorkloadMeta::Cg { n: 16, shards: 2 };
        let ck = Checkpoint::new(
            epoch,
            CheckpointPayload::Cg {
                x: (0..16).map(|i| (i as f64).sin()).collect(),
                r: (0..16).map(|i| (i as f64).cos()).collect(),
                p: vec![-0.0; 16],
                rr: 3.25e-12,
                iters_done: 7,
                iters_target: 40,
                segs: vec![16, 17],
                resubmits: 0,
            },
        );
        (meta, ck)
    }

    fn payload_bits(ck: &Checkpoint) -> Vec<u64> {
        match &ck.payload {
            CheckpointPayload::Stencil { grid, slabs, residual, .. } => {
                let mut v: Vec<u64> = grid.iter().map(|x| x.to_bits()).collect();
                for (c, n) in slabs {
                    v.extend(c.iter().map(|x| x.to_bits()));
                    v.extend(n.iter().map(|x| x.to_bits()));
                }
                v.push(residual.unwrap_or(0.0).to_bits());
                v
            }
            CheckpointPayload::Cg { x, r, p, rr, .. } => {
                let mut v: Vec<u64> = x.iter().map(|y| y.to_bits()).collect();
                v.extend(r.iter().map(|y| y.to_bits()));
                v.extend(p.iter().map(|y| y.to_bits()));
                v.push(rr.to_bits());
                v
            }
        }
    }

    #[test]
    fn persist_restore_round_trips_bit_identically() {
        let root = tmp_root("roundtrip");
        let store = SnapshotStore::open(&root).unwrap();
        let frames0 = counters::durable_frames();
        let restores0 = counters::restores();

        let (smeta, sck) = stencil_ck(16, 0.5);
        let (cmeta, cck) = cg_ck(7);
        assert_eq!(store.persist("stencil-0", &smeta, &sck).unwrap(), 1);
        assert_eq!(store.persist("cg-1", &cmeta, &cck).unwrap(), 1);
        assert!(counters::durable_frames() >= frames0 + 2);
        assert!(counters::durable_bytes() > 0);

        let got = store.restore("stencil-0").unwrap();
        assert_eq!(got.generation, 1);
        assert_eq!(got.fallbacks, 0);
        assert_eq!(got.meta, smeta);
        assert_eq!(got.checkpoint.epoch, 16);
        assert_eq!(payload_bits(&got.checkpoint), payload_bits(&sck));

        let got = store.restore("cg-1").unwrap();
        assert_eq!(got.meta, cmeta);
        assert_eq!(payload_bits(&got.checkpoint), payload_bits(&cck));
        assert_eq!(got.checkpoint.progress(), (7, 40));
        assert!(counters::restores() >= restores0 + 2);

        assert_eq!(store.tenants().unwrap(), vec!["cg-1".to_string(), "stencil-0".to_string()]);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn generations_advance_and_prune_to_keep() {
        let root = tmp_root("prune");
        let store = SnapshotStore::open(&root).unwrap().with_keep(2);
        for epoch in 1..=5u64 {
            let (meta, ck) = cg_ck(epoch);
            assert_eq!(store.persist("t", &meta, &ck).unwrap(), epoch);
        }
        let entries = store.entries("t").unwrap();
        let gens: Vec<u64> = entries.iter().map(|e| e.generation).collect();
        assert_eq!(gens, vec![4, 5], "keep=2 retains the newest two");
        // pruned frame files are actually gone
        assert!(!root.join("t").join(frame_name(1)).exists());
        assert!(root.join("t").join(frame_name(5)).exists());
        let got = store.restore("t").unwrap();
        assert_eq!((got.generation, got.checkpoint.epoch), (5, 5));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_and_corrupt_frames_fall_back_a_generation() {
        let root = tmp_root("fallback");
        let store = SnapshotStore::open(&root).unwrap();
        let (meta, ck1) = cg_ck(8);
        let (_, ck2) = cg_ck(16);
        store.persist("t", &meta, &ck1).unwrap();
        store.persist("t", &meta, &ck2).unwrap();

        // truncate the newest frame (torn write that somehow got named)
        let newest = root.join("t").join(frame_name(2));
        let bytes = fs::read(&newest).unwrap();
        fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let got = store.restore("t").unwrap();
        assert_eq!((got.generation, got.fallbacks), (1, 1));
        assert_eq!(payload_bits(&got.checkpoint), payload_bits(&ck1));

        // flip one payload byte in the newest frame: checksum catches it
        let mut bytes = bytes;
        let at = HEADER_LEN + 40;
        bytes[at] ^= 0x10;
        fs::write(&newest, &bytes).unwrap();
        let got = store.restore("t").unwrap();
        assert_eq!((got.generation, got.fallbacks), (1, 1));

        // verify() reports exactly which generation is sick
        let statuses = store.verify("t").unwrap();
        assert_eq!(statuses.len(), 2);
        assert!(statuses.iter().any(|s| s.generation == 1 && s.problem.is_none()));
        assert!(statuses.iter().any(|s| s.generation == 2 && s.problem.is_some()));

        // both generations corrupt -> structured error, not a panic
        let older = root.join("t").join(frame_name(1));
        fs::write(&older, b"PKSF garbage").unwrap();
        let err = store.restore("t").unwrap_err();
        assert!(matches!(err, Error::Snapshot(_)), "{err}");
        assert!(format!("{err}").contains("no generation verified"), "{err}");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn unmanifested_frames_and_stale_tmps_are_ignored_then_cleaned() {
        let root = tmp_root("stale");
        let store = SnapshotStore::open(&root).unwrap();
        let (meta, ck) = cg_ck(4);
        store.persist("t", &meta, &ck).unwrap();

        // an unmanifested frame (crash between frame and manifest
        // renames) and a stale tmp (crash mid-write) appear
        let dir = root.join("t");
        fs::write(dir.join(frame_name(9)), b"not a committed frame").unwrap();
        fs::write(dir.join("gen-10.frame.tmp"), b"torn tmp").unwrap();

        // restore walks the manifest only: the garbage is invisible
        let got = store.restore("t").unwrap();
        assert_eq!((got.generation, got.fallbacks), (1, 0));

        // the next persist allocates ABOVE the unmanifested file and
        // cleans both leftovers
        let (_, ck2) = cg_ck(8);
        assert_eq!(store.persist("t", &meta, &ck2).unwrap(), 10);
        assert!(!dir.join(frame_name(9)).exists(), "unmanifested frame pruned");
        assert!(!dir.join("gen-10.frame.tmp").exists(), "stale tmp pruned");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn missing_or_corrupt_manifest_is_a_structured_error() {
        let root = tmp_root("manifest");
        let store = SnapshotStore::open(&root).unwrap();
        // no directory at all
        let err = store.restore("ghost").unwrap_err();
        assert!(matches!(err, Error::Snapshot(_)), "{err}");
        // corrupt manifest bytes
        let (meta, ck) = cg_ck(2);
        store.persist("t", &meta, &ck).unwrap();
        fs::write(root.join("t").join(MANIFEST), b"scrambled").unwrap();
        let err = store.restore("t").unwrap_err();
        assert!(format!("{err}").contains("manifest"), "{err}");
        // a fresh persist recovers the directory with a new chain
        let gen = store.persist("t", &meta, &ck).unwrap();
        assert!(gen >= 2, "new chain allocates above surviving frame files");
        assert_eq!(store.restore("t").unwrap().generation, gen);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn tenant_names_cannot_escape_the_root() {
        let root = tmp_root("names");
        let store = SnapshotStore::open(&root).unwrap();
        let (meta, ck) = cg_ck(1);
        for bad in ["", "..", "../evil", "a/b", ".hidden", "x y", &"t".repeat(65)] {
            let err = store.persist(bad, &meta, &ck).unwrap_err();
            assert!(matches!(err, Error::Snapshot(_)), "{bad:?}: {err}");
        }
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn workload_meta_describes_itself() {
        let m = WorkloadMeta::Stencil { bench: "3d7pt".into(), dims: vec![8, 8, 8], bt: 2, shards: 4 };
        assert_eq!(m.describe(), "stencil 3d7pt 8x8x8 bt=2 shards=4");
        assert_eq!(WorkloadMeta::Cg { n: 64, shards: 2 }.describe(), "cg n=64 shards=2");
    }
}
