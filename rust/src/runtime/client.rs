//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` -> `HloModuleProto::
//! from_text_file` -> `compile` -> `execute`). Executables are cached per
//! artifact name, and simple traffic metrics are kept so benches can report
//! host<->device marshalling cost (the analog of the paper's global-memory
//! round trip in the host-loop execution model).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::runtime::tensor::HostTensor;

/// Cumulative runtime metrics (interior mutability: reads take `&self`).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeMetrics {
    /// Number of executable invocations (kernel launches).
    pub invocations: u64,
    /// Bytes marshalled host -> device (literal uploads).
    pub bytes_in: u64,
    /// Bytes marshalled device -> host (literal downloads).
    pub bytes_out: u64,
    /// Number of artifact compilations (cache misses).
    pub compilations: u64,
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    metrics: Rc<RefCell<RuntimeMetrics>>,
}

impl Executable {
    /// Execute with host tensors, returning host tensors.
    ///
    /// Inputs are validated against the artifact signature. If the artifact
    /// was lowered with `return_tuple=True` the single tuple result is
    /// decomposed; otherwise the outputs are read positionally.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        {
            let mut m = self.metrics.borrow_mut();
            m.invocations += 1;
            m.bytes_in += inputs.iter().map(|t| t.bytes() as u64).sum::<u64>();
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        self.collect_outputs(&result)
    }

    /// Execute reusing device buffers (no host round trip for inputs).
    /// Used by the device-resident host-loop baseline with `raw` artifacts.
    pub fn run_buffers(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        self.metrics.borrow_mut().invocations += 1;
        Ok(self.exe.execute_b::<&xla::PjRtBuffer>(&inputs.iter().collect::<Vec<_>>())?)
    }

    /// Upload host tensors to device buffers by executing nothing: we use
    /// `execute` with literals on the identity-free path; PJRT has no
    /// direct host->buffer API in this crate version, so buffer chains are
    /// seeded by the first `execute` call's outputs.
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        self.metrics.borrow_mut().invocations += 1;
        Ok(self.exe.execute::<xla::Literal>(inputs)?)
    }

    fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            )));
        }
        for (t, spec) in inputs.iter().zip(&self.meta.inputs) {
            t.check(spec)?;
        }
        Ok(())
    }

    /// Download + decompose results into host tensors.
    pub fn collect_outputs(&self, result: &[Vec<xla::PjRtBuffer>]) -> Result<Vec<HostTensor>> {
        let buffers = result
            .first()
            .ok_or_else(|| Error::Shape(format!("{}: empty result", self.meta.name)))?;
        let mut outs = Vec::with_capacity(self.meta.outputs.len());
        if self.meta.tupled {
            let lit = buffers[0].to_literal_sync()?;
            let parts = lit.to_tuple()?;
            if parts.len() != self.meta.outputs.len() {
                return Err(Error::Shape(format!(
                    "{}: tuple arity {} != manifest outputs {}",
                    self.meta.name,
                    parts.len(),
                    self.meta.outputs.len()
                )));
            }
            for (part, spec) in parts.iter().zip(&self.meta.outputs) {
                outs.push(HostTensor::from_literal(part, spec)?);
            }
        } else {
            if buffers.len() != self.meta.outputs.len() {
                return Err(Error::Shape(format!(
                    "{}: got {} output buffers, manifest says {}",
                    self.meta.name,
                    buffers.len(),
                    self.meta.outputs.len()
                )));
            }
            for (buf, spec) in buffers.iter().zip(&self.meta.outputs) {
                let lit = buf.to_literal_sync()?;
                outs.push(HostTensor::from_literal(&lit, spec)?);
            }
        }
        self.metrics.borrow_mut().bytes_out +=
            outs.iter().map(|t| t.bytes() as u64).sum::<u64>();
        Ok(outs)
    }
}

/// The runtime: a PJRT CPU client + artifact registry + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    metrics: Rc<RefCell<RuntimeMetrics>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory (containing
    /// `manifest.txt` and the `.hlo.txt` files).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            dir,
            cache: RefCell::new(HashMap::new()),
            metrics: Rc::new(RefCell::new(RuntimeMetrics::default())),
        })
    }

    /// Resolve the default artifact directory: `$PERKS_ARTIFACTS` or
    /// `./artifacts` relative to the working directory.
    pub fn default_dir() -> PathBuf {
        std::env::var("PERKS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-once, cached) an executable by artifact name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.metrics.borrow_mut().compilations += 1;
        let exe = Rc::new(Executable { meta, exe, metrics: self.metrics.clone() });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// One-shot convenience: load + run.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?.run(inputs)
    }

    pub fn metrics(&self) -> RuntimeMetrics {
        *self.metrics.borrow()
    }

    pub fn reset_metrics(&self) {
        *self.metrics.borrow_mut() = RuntimeMetrics::default();
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }
}
