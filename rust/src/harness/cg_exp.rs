//! CG experiment builders: the Fig 7 (speedup over Ginkgo + sustained BW)
//! and Fig 9 (policy heatmap) rows.
//!
//! Iteration-time model (constants documented in DESIGN.md §5):
//!
//! * baseline (Ginkgo-like): `K_LAUNCHES` kernel launches per iteration
//!   (SpMV, 2 dots, 2 axpy-likes + overhead) at `T_LAUNCH` each, plus the
//!   uncached per-iteration traffic streamed at the effective bandwidth of
//!   the level the working set fits in (L2 vs HBM);
//! * PERKS: `K_SYNCS` grid barriers at `T_SYNC` each (Zhang et al.: barrier
//!   cost ~ relaunch cost, but PERKS needs far fewer synchronization points
//!   than the baseline needs launches, and fuses the BLAS-1 passes), plus
//!   the policy-reduced traffic, with the cached share served from
//!   smem/register bandwidth.

use crate::cg::policy::CgPolicy;
use crate::coordinator::executor::ExecMode;
use crate::harness::{ModeledRun, HOST_LINK_BW};
use crate::simgpu::device::DeviceSpec;
use crate::sparse::datasets::Dataset;

/// Launch / sync cost constants (seconds).
pub const T_LAUNCH: f64 = 4.0e-6;
pub const T_SYNC: f64 = 1.6e-6;
/// Kernel launches per baseline CG iteration (Ginkgo's CG does SpMV + 4-6
/// BLAS-1/reduction kernels).
pub const K_LAUNCHES: f64 = 6.0;
/// Grid syncs per PERKS CG iteration (after SpMV, after the dot, after
/// the update).
pub const K_SYNCS: f64 = 3.0;

/// Effective streaming bandwidth for a working set of `bytes`.
pub fn effective_bw(dev: &DeviceSpec, bytes: f64) -> f64 {
    if bytes <= dev.l2_bytes as f64 {
        // L2 streams ~3x HBM on these parts
        3.0 * dev.gmem_bw
    } else {
        dev.gmem_bw
    }
}

/// On-chip capacity available to the PERKS CG kernel for caching
/// (minimum occupancy; merge-SpMV kernel is lean: ~40 regs, 2KB smem/TB).
pub fn cg_cache_capacity(dev: &DeviceSpec) -> f64 {
    let used_regs_per_smx = 128.0 * 40.0 * 4.0; // 128 threads x 40 regs
    let used_smem_per_smx = 2048.0;
    let free = (dev.regfile_per_smx() as f64 - used_regs_per_smx) * 0.73
        + (dev.smem_per_smx() as f64 - used_smem_per_smx);
    // only ~half the freed capacity is practically usable for irregular
    // SpMV data (alignment, per-TB partitioning slack, the §IV-E register
    // reuse inefficiency); calibrated against the paper's beyond-L2
    // speedups (1.15-1.6x)
    free * dev.smxs as f64 * 0.5
}

/// One Fig 7 / Fig 9 evaluation.
#[derive(Clone, Debug)]
pub struct CgRow {
    pub code: &'static str,
    pub name: &'static str,
    pub rows: usize,
    pub nnz: usize,
    pub within_l2: bool,
    /// Speedup per policy, ordered as CgPolicy::all().
    pub speedups: Vec<(CgPolicy, f64)>,
    /// Baseline ("Ginkgo") sustained bandwidth, bytes/s.
    pub baseline_bw: f64,
}

impl CgRow {
    pub fn best(&self) -> (CgPolicy, f64) {
        *self
            .speedups
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
    }

    pub fn speedup(&self, p: CgPolicy) -> f64 {
        self.speedups.iter().find(|(q, _)| *q == p).unwrap().1
    }
}

/// Evaluate one dataset on one device at paper scale (`elem` = 4 for sp,
/// 8 for dp). The matrix itself is only needed for its (rows, nnz), so we
/// evaluate from the Table V entries directly.
pub fn evaluate(dev: &DeviceSpec, ds: &Dataset, elem: usize) -> CgRow {
    // build a tiny stand-in CSR with the paper's rows/nnz for the traffic
    // accounting (policy_traffic only reads n_rows/nnz)
    let a = CsrShape { n_rows: ds.paper_rows, nnz: ds.paper_nnz };
    let working_set =
        (a.nnz * (elem + 4) + (a.n_rows + 1) * 4 + 4 * a.n_rows * elem) as f64;
    let within_l2 = working_set <= dev.l2_bytes as f64;
    let bw = effective_bw(dev, working_set);

    let base_traffic = baseline_traffic_bytes(&a, elem);
    let t_base = K_LAUNCHES * T_LAUNCH + base_traffic / bw;
    let baseline_bw = base_traffic / t_base;

    let capacity = cg_cache_capacity(dev);
    let speedups = CgPolicy::all()
        .into_iter()
        .map(|p| {
            let traffic = policy_traffic_bytes(&a, elem, p, capacity);
            // cached share is served from on-chip bandwidth — model it as
            // free relative to HBM (smem BW >> HBM BW); the uncached share
            // streams at `bw`.
            let t_perks = K_SYNCS * T_SYNC + traffic / bw;
            (p, t_base / t_perks)
        })
        .collect();
    CgRow {
        code: ds.code,
        name: ds.name,
        rows: ds.paper_rows,
        nnz: ds.paper_nnz,
        within_l2,
        speedups,
        baseline_bw,
    }
}

/// Minimal shape carrier so we can account traffic without materializing
/// multi-GB matrices.
struct CsrShape {
    n_rows: usize,
    nnz: usize,
}

fn baseline_traffic_bytes(a: &CsrShape, elem: usize) -> f64 {
    // matrix: vals+cols once, row_ptr once; vectors: 10 passes (Ginkgo
    // already fuses some BLAS-1 work — it is a tuned baseline, not the
    // naive 13-pass loop of cg::policy::baseline_traffic); workload
    // search: one row_ptr pass
    (a.nnz * (elem + 4) + (a.n_rows + 1) * 4) as f64
        + (10 * a.n_rows * elem) as f64
        + ((a.n_rows + 1) * 4) as f64
}

fn policy_traffic_bytes(a: &CsrShape, elem: usize, p: CgPolicy, capacity: f64) -> f64 {
    // mirror cg::policy::policy_traffic but over the shape carrier;
    // PERKS always fuses the BLAS-1 passes: 13 -> 8 vector passes
    let matrix_stream = (a.nnz * (elem + 4) + (a.n_rows + 1) * 4) as f64;
    let vector_stream = (8 * a.n_rows * elem) as f64;
    let workload = ((a.n_rows + 1) * 4) as f64;
    let matrix_bytes = (a.nnz * (elem + 4)) as f64;
    let vector_bytes = (4 * a.n_rows * elem) as f64;
    let (vec_frac, mat_frac) = match p {
        CgPolicy::Imp => (0.0, 0.0),
        CgPolicy::Vec => ((capacity / vector_bytes).min(1.0), 0.0),
        CgPolicy::Mat => (0.0, (capacity / matrix_bytes).min(1.0)),
        CgPolicy::Mix => {
            let vf = (capacity / vector_bytes).min(1.0);
            let rest = (capacity - vf * vector_bytes).max(0.0);
            (vf, (rest / matrix_bytes).min(1.0))
        }
    };
    let workload = if p == CgPolicy::Imp { workload } else { 0.0 };
    matrix_stream * (1.0 - mat_frac) + vector_stream * (1.0 - vec_frac) + workload
}

/// Model `iters` CG iterations on one device under an execution model —
/// the engine of `session::Backend::Simulated` for CG workloads. Uses the
/// same per-iteration launch/sync + traffic model as `evaluate` (Fig 7),
/// with the persistent model running at its best caching policy.
pub fn modeled_cg_run(
    dev: &DeviceSpec,
    rows: usize,
    nnz: usize,
    elem: usize,
    mode: ExecMode,
    iters: usize,
) -> ModeledRun {
    let a = CsrShape { n_rows: rows, nnz };
    let working_set =
        (a.nnz * (elem + 4) + (a.n_rows + 1) * 4 + 4 * a.n_rows * elem) as f64;
    let bw = effective_bw(dev, working_set);
    let state_bytes = (4 * rows * elem) as f64; // x, r, p, Ap
    let matrix_bytes = (nnz * (elem + 4) + (rows + 1) * 4) as f64;
    match mode {
        ExecMode::Persistent | ExecMode::Pipelined => {
            let capacity = cg_cache_capacity(dev);
            let traffic = CgPolicy::all()
                .into_iter()
                .map(|p| policy_traffic_bytes(&a, elem, p, capacity))
                .fold(f64::INFINITY, f64::min);
            // classic persistent CG pays K_SYNCS grid syncs per
            // iteration; the pipelined formulation folds everything
            // through exactly one, trading ~1.5x vector traffic (the
            // w/s/q/z/m auxiliary recurrences) for the collapsed syncs
            let (syncs, traffic) = match mode {
                ExecMode::Pipelined => (1.0, traffic * 1.5),
                _ => (K_SYNCS, traffic),
            };
            let barrier = iters as f64 * syncs * T_SYNC;
            ModeledRun {
                wall_seconds: iters as f64 * traffic / bw
                    + barrier
                    + T_LAUNCH
                    + (matrix_bytes + 2.0 * state_bytes) / HOST_LINK_BW,
                invocations: 1,
                host_bytes: (matrix_bytes + 2.0 * state_bytes) as u64,
                barrier_wait_seconds: barrier,
            }
        }
        _ => {
            // host-loop (and resident, which the CG artifacts do not
            // distinguish): every iteration relaunches and re-streams
            let t_iter = K_LAUNCHES * T_LAUNCH + baseline_traffic_bytes(&a, elem) / bw;
            let per_iter_host = matrix_bytes + 2.0 * state_bytes;
            ModeledRun {
                wall_seconds: iters as f64 * (t_iter + per_iter_host / HOST_LINK_BW),
                invocations: iters as u64,
                host_bytes: (iters as f64 * per_iter_host) as u64,
                barrier_wait_seconds: 0.0,
            }
        }
    }
}

/// All twenty Table V rows for a device/precision.
pub fn fig7(dev: &DeviceSpec, elem: usize) -> Vec<CgRow> {
    crate::sparse::datasets::table_v().iter().map(|d| evaluate(dev, d, elem)).collect()
}

/// One **measured** (not modeled) CPU CG mode from [`measure_cpu_cg_modes`].
#[derive(Clone, Debug)]
pub struct MeasuredCgMode {
    pub mode: ExecMode,
    pub wall_seconds: f64,
    /// Launches: 1 for the pooled persistent advance, `iters` host-loop.
    pub invocations: u64,
    /// OS threads spawned *during* `advance` — 0 for the pool (spawned at
    /// prepare), `iters * workers` for the spawn-per-iteration baseline.
    pub advance_spawns: u64,
    pub iters_per_sec: f64,
}

impl MeasuredCgMode {
    /// Stable BENCH-json fragment, shared by the benches that report this
    /// measurement so the schema cannot drift between them.
    pub fn json(&self) -> String {
        format!(
            "{{\"mode\":\"{}\",\"wall_seconds\":{:.6},\"invocations\":{},\"advance_spawns\":{}}}",
            self.mode.key(),
            self.wall_seconds,
            self.invocations,
            self.advance_spawns
        )
    }
}

/// Measure spawn-per-iteration host-loop vs pooled persistent CG on an
/// `n`-row Poisson system through the session API (threaded, fixed
/// iteration count), snapshotting the thread-spawn counter around each
/// `advance`. One shared protocol for `perf_hotpath` and `fig7_cg`.
pub fn measure_cpu_cg_modes(
    n: usize,
    iters: usize,
    threads: usize,
    parts: usize,
) -> crate::error::Result<Vec<MeasuredCgMode>> {
    use crate::session::{Backend, SessionBuilder};
    let mut out = Vec::new();
    for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
        let mut s = SessionBuilder::cg(n)
            .parts(parts)
            .threaded(true)
            .backend(Backend::cpu(threads))
            .mode(mode)
            .build()?;
        // build() already prepared the solver — the pool (persistent
        // mode) spawned its workers there, not in advance
        let spawns0 = crate::util::counters::thread_spawns();
        s.advance(iters)?;
        let advance_spawns = crate::util::counters::thread_spawns() - spawns0;
        let rep = s.report();
        out.push(MeasuredCgMode {
            mode,
            wall_seconds: rep.wall_seconds,
            invocations: rep.invocations,
            advance_spawns,
            iters_per_sec: rep.fom,
        });
    }
    Ok(out)
}

/// One **measured** arm of the classic-vs-pipelined pooled CG ablation
/// from [`measure_cpu_cg_pipeline`].
#[derive(Clone, Debug)]
pub struct MeasuredCgPipelineArm {
    pub mode: ExecMode,
    pub wall_seconds: f64,
    /// Launches: 1 — both arms are resident pools.
    pub invocations: u64,
    /// OS threads spawned *during* `advance` — 0 for both arms (workers
    /// spawn at `prepare`).
    pub advance_spawns: u64,
    /// Slot-ordered barrier reduction generations paid *during* `advance`:
    /// exactly `2 * iters` for the classic arm (p·Ap, then r·r), exactly
    /// `iters` for the pipelined arm. Exact only in a single-threaded
    /// bench main — the counter is process-global.
    pub barrier_reductions: u64,
    pub iters_per_sec: f64,
}

impl MeasuredCgPipelineArm {
    /// Stable BENCH-json row of `BENCH_cg_pipeline.json` (`n` is the
    /// system size the arm ran at; the mode string is [`ExecMode::key`]).
    pub fn json(&self, n: usize) -> String {
        format!(
            "{{\"n\":{n},\"mode\":\"{}\",\"wall_seconds\":{:.6},\"invocations\":{},\
             \"advance_spawns\":{},\"barrier_reductions\":{}}}",
            self.mode.key(),
            self.wall_seconds,
            self.invocations,
            self.advance_spawns,
            self.barrier_reductions
        )
    }
}

/// Measure classic pooled CG (two reduction barriers per iteration)
/// against pipelined pooled CG (one) on an `n`-row Poisson system through
/// the session API, snapshotting the thread-spawn AND barrier-reduction
/// counters around each `advance`. The `benches/cg_pipeline` protocol
/// behind the `pipelined-single-reduction` and `pipelined-wall-win`
/// bench_check gates.
pub fn measure_cpu_cg_pipeline(
    n: usize,
    iters: usize,
    threads: usize,
    parts: usize,
) -> crate::error::Result<Vec<MeasuredCgPipelineArm>> {
    use crate::session::{Backend, SessionBuilder};
    let mut out = Vec::new();
    for mode in [ExecMode::Persistent, ExecMode::Pipelined] {
        let mut s = SessionBuilder::cg(n)
            .parts(parts)
            .threaded(true)
            .backend(Backend::cpu(threads))
            .mode(mode)
            .build()?;
        let spawns0 = crate::util::counters::thread_spawns();
        let reductions0 = crate::util::counters::barrier_reductions();
        s.advance(iters)?;
        let advance_spawns = crate::util::counters::thread_spawns() - spawns0;
        let barrier_reductions = crate::util::counters::barrier_reductions() - reductions0;
        let rep = s.report();
        out.push(MeasuredCgPipelineArm {
            mode,
            wall_seconds: rep.wall_seconds,
            invocations: rep.invocations,
            advance_spawns,
            barrier_reductions,
            iters_per_sec: rep.fom,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::{a100, v100};
    use crate::util::stats::geomean;

    fn split_geomeans(dev: &DeviceSpec, elem: usize) -> (f64, f64) {
        let rows = fig7(dev, elem);
        let within: Vec<f64> =
            rows.iter().filter(|r| r.within_l2).map(|r| r.best().1).collect();
        let beyond: Vec<f64> =
            rows.iter().filter(|r| !r.within_l2).map(|r| r.best().1).collect();
        (geomean(&within), geomean(&beyond))
    }

    #[test]
    fn modeled_cg_run_persistent_beats_host_loop() {
        let dev = a100();
        // poisson2d(32)-sized system, paper-style fixed iteration count
        let h = modeled_cg_run(&dev, 1024, 4992, 4, ExecMode::HostLoop, 100);
        let p = modeled_cg_run(&dev, 1024, 4992, 4, ExecMode::Persistent, 100);
        assert!(p.wall_seconds < h.wall_seconds, "{} vs {}", p.wall_seconds, h.wall_seconds);
        assert_eq!(p.invocations, 1);
        assert_eq!(h.invocations, 100);
        assert!(h.host_bytes > p.host_bytes);
        assert!(p.barrier_wait_seconds > 0.0);
    }

    #[test]
    fn fig7_shape_within_l2_much_faster() {
        // paper: within-L2 speedups 4.3-5.1x, beyond 1.15-1.6x
        for dev in [a100(), v100()] {
            for elem in [4, 8] {
                let (w, b) = split_geomeans(&dev, elem);
                assert!(w > 2.0 && w < 10.0, "{} elem{elem}: within {w}", dev.name);
                assert!(b > 1.0 && b < 2.5, "{} elem{elem}: beyond {b}", dev.name);
                assert!(w > 2.0 * b, "{}: crossover missing {w} vs {b}", dev.name);
            }
        }
    }

    #[test]
    fn fig9_imp_gains_even_without_explicit_caching() {
        // paper: IMP achieves 3.61x within L2, 1.19x beyond
        let rows = fig7(&a100(), 8);
        let within: Vec<f64> = rows
            .iter()
            .filter(|r| r.within_l2)
            .map(|r| r.speedup(CgPolicy::Imp))
            .collect();
        let g = geomean(&within);
        assert!(g > 1.5, "IMP within L2 should already win: {g}");
        let beyond: Vec<f64> = rows
            .iter()
            .filter(|r| !r.within_l2)
            .map(|r| r.speedup(CgPolicy::Imp))
            .collect();
        let gb = geomean(&beyond);
        assert!(gb > 1.0 && gb < 1.6, "IMP beyond L2 modest: {gb}");
    }

    #[test]
    fn fig9_more_caching_more_speedup() {
        // general tendency: MIX >= VEC >= IMP (paper §VI-G2 third point)
        let rows = fig7(&a100(), 4);
        let mut holds = 0;
        for r in &rows {
            if r.speedup(CgPolicy::Mix) + 1e-9 >= r.speedup(CgPolicy::Vec)
                && r.speedup(CgPolicy::Vec) + 1e-9 >= r.speedup(CgPolicy::Imp)
            {
                holds += 1;
            }
        }
        assert!(holds >= 18, "monotone policy ordering holds for {holds}/20");
    }

    #[test]
    fn vec_insufficient_alone_for_large_sets() {
        // §VI-G2: vectors are small; VEC ~ IMP for big matrices
        let rows = fig7(&a100(), 8);
        let big = rows.iter().find(|r| r.code == "D20").unwrap();
        let vec_gain = big.speedup(CgPolicy::Vec) / big.speedup(CgPolicy::Imp);
        assert!(vec_gain < 1.3, "VEC alone should be modest on D20: {vec_gain}");
    }

    #[test]
    fn baseline_bw_below_device_peak() {
        for r in fig7(&a100(), 8) {
            assert!(r.baseline_bw < 3.0 * a100().gmem_bw * 1.01, "{}", r.code);
            assert!(r.baseline_bw > 0.0);
        }
    }
}
