//! Bench harness: experiment builders + report emitters that regenerate
//! every table and figure of the paper's evaluation (see DESIGN.md §5 for
//! the index). The `benches/` binaries are thin wrappers over this module,
//! and the `session::Backend::Simulated` solvers drive the same machinery
//! through [`stencil_exp::modeled_run`] / [`cg_exp::modeled_cg_run`].

pub mod cg_exp;
pub mod farm_exp;
pub mod plane_exp;
pub mod resilience_exp;
pub mod stencil_exp;

pub use cg_exp::{
    evaluate as cg_evaluate, fig7, measure_cpu_cg_modes, measure_cpu_cg_pipeline,
    modeled_cg_run, CgRow, MeasuredCgMode, MeasuredCgPipelineArm,
};
pub use farm_exp::{farm_vs_pool_per_session, FarmSweepRow};
pub use plane_exp::{plane_stress, PlaneStressRow};
pub use resilience_exp::{
    cg_cadence_sweep, cg_durable_sweep, cg_recovery_row, stencil_cadence_sweep,
    stencil_durable_sweep, stencil_recovery_row, ResilienceRow,
};
pub use stencil_exp::{
    measure_cpu_stencil_modes, measure_cpu_stencil_temporal, modeled_run, speedup_row,
    MeasuredStencilMode, StencilExperiment,
};

/// Nominal host-link (PCIe-class) bandwidth used by the simulated backend
/// to cost the host round trip of the `host-loop` execution model. The
/// paper's testbeds are PCIe 4.0 x16 / NVLink hosts; 25 GB/s is the
/// measured-transfer ballpark for pageable copies.
pub const HOST_LINK_BW: f64 = 25e9;

/// Modeled cost of one run on the simulated backend (consumed by
/// `session::Backend::Simulated`; mirrors the fields of a measured
/// `session::Report`).
#[derive(Clone, Copy, Debug)]
pub struct ModeledRun {
    pub wall_seconds: f64,
    pub invocations: u64,
    pub host_bytes: u64,
    pub barrier_wait_seconds: f64,
}

use crate::cg::policy::CgPolicy;
use crate::coordinator::caching::CacheLocation;
use crate::simgpu::device::DeviceSpec;
use crate::simgpu::perfmodel;
use crate::util::fmt::Table;
use crate::util::stats::geomean;

/// Render the Fig 5 (large domains) or Fig 6 (small domains) table for a
/// device pair.
pub fn render_stencil_speedups(devs: &[DeviceSpec], elem: usize, small: bool) -> String {
    let steps = 1000;
    let eff = if small { perfmodel::EFF_PERKS_SMALL } else { perfmodel::EFF_PERKS_LARGE };
    let mut header = vec!["bench".to_string(), "domain".to_string()];
    for d in devs {
        header.push(format!("{} speedup", d.name));
        header.push(format!("{} best", d.name));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&header_refs);
    let mut per_dev: Vec<Vec<f64>> = vec![Vec::new(); devs.len()];
    let benches: Vec<&str> = stencil_exp::benches_2d()
        .into_iter()
        .chain(stencil_exp::benches_3d())
        .collect();
    for b in benches {
        let mut cells = Vec::new();
        let mut domain_str = String::new();
        for (i, d) in devs.iter().enumerate() {
            let exp = if small {
                StencilExperiment::small(d, b, elem, steps)
            } else {
                StencilExperiment::large(d, b, elem, steps)
            };
            let row = speedup_row(d, &exp, eff);
            per_dev[i].push(row.speedup);
            if i == 0 {
                domain_str = row
                    .domain
                    .iter()
                    .map(|x| x.to_string())
                    .collect::<Vec<_>>()
                    .join("x");
            }
            cells.push(format!("{:.2}x", row.speedup));
            cells.push(row.best_location.name().to_string());
        }
        let mut all = vec![b.to_string(), domain_str];
        all.extend(cells);
        t.row(&all);
    }
    let mut out = t.render();
    for (i, d) in devs.iter().enumerate() {
        out.push_str(&format!("{} geomean: {:.2}x\n", d.name, geomean(&per_dev[i])));
    }
    out
}

/// Render the Fig 8 cache-location heatmap for one device.
pub fn render_fig8(dev: &DeviceSpec, elem: usize) -> String {
    let mut t = Table::new(&["bench", "IMP", "SM", "REG", "BTH"]);
    let benches: Vec<&str> = stencil_exp::benches_2d()
        .into_iter()
        .chain(stencil_exp::benches_3d())
        .collect();
    for b in benches {
        let exp = StencilExperiment::large(dev, b, elem, 1000);
        let rows = stencil_exp::location_row(dev, &exp, perfmodel::EFF_PERKS_LARGE);
        let get = |loc: CacheLocation| {
            rows.iter().find(|(l, _)| *l == loc).map(|(_, s)| format!("{s:.2}x")).unwrap()
        };
        t.row(&[
            b.to_string(),
            get(CacheLocation::Implicit),
            get(CacheLocation::SharedOnly),
            get(CacheLocation::RegOnly),
            get(CacheLocation::Both),
        ]);
    }
    t.render()
}

/// Render Fig 7 (CG speedup + sustained baseline BW) for one device.
pub fn render_fig7(dev: &DeviceSpec, elem: usize) -> String {
    let rows = fig7(dev, elem);
    let mut t = Table::new(&["code", "name", "rows", "nnz", "L2", "best", "speedup", "ginkgo BW"]);
    for r in &rows {
        let (p, s) = r.best();
        t.row(&[
            r.code.to_string(),
            r.name.to_string(),
            r.rows.to_string(),
            r.nnz.to_string(),
            if r.within_l2 { "within".into() } else { "exceeds".to_string() },
            p.name().to_string(),
            format!("{s:.2}x"),
            crate::util::fmt::gbps(r.baseline_bw),
        ]);
    }
    let within: Vec<f64> = rows.iter().filter(|r| r.within_l2).map(|r| r.best().1).collect();
    let beyond: Vec<f64> = rows.iter().filter(|r| !r.within_l2).map(|r| r.best().1).collect();
    let mut out = t.render();
    out.push_str(&format!(
        "geomean within-L2: {:.2}x   beyond-L2: {:.2}x\n",
        geomean(&within),
        geomean(&beyond)
    ));
    out
}

/// Render Fig 9 (CG policy heatmap) for one device.
pub fn render_fig9(dev: &DeviceSpec, elem: usize) -> String {
    let rows = fig7(dev, elem);
    let mut t = Table::new(&["code", "L2", "IMP", "VEC", "MAT", "MIX"]);
    for r in &rows {
        t.row(&[
            r.code.to_string(),
            if r.within_l2 { "w".into() } else { "x".to_string() },
            format!("{:.2}x", r.speedup(CgPolicy::Imp)),
            format!("{:.2}x", r.speedup(CgPolicy::Vec)),
            format!("{:.2}x", r.speedup(CgPolicy::Mat)),
            format!("{:.2}x", r.speedup(CgPolicy::Mix)),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::{a100, v100};

    #[test]
    fn renders_are_nonempty_and_have_all_benchmarks() {
        let s = render_stencil_speedups(&[a100(), v100()], 8, false);
        assert!(s.contains("2d5pt") && s.contains("poisson") && s.contains("geomean"));
        let f8 = render_fig8(&a100(), 8);
        assert_eq!(f8.lines().count(), 2 + 13);
        let f7 = render_fig7(&a100(), 4);
        assert!(f7.contains("D20") && f7.contains("geomean"));
        let f9 = render_fig9(&v100(), 8);
        assert_eq!(f9.lines().count(), 2 + 20);
    }
}
