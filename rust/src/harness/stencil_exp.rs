//! Stencil experiment builders: glue occupancy + caching + perfmodel into
//! the rows of Figs 1/5/6/8 and Tables II/IV.

use crate::coordinator::caching::{self, CacheLocation};
use crate::coordinator::executor::ExecMode;
use crate::harness::cg_exp::{T_LAUNCH, T_SYNC};
use crate::harness::{ModeledRun, HOST_LINK_BW};
use crate::simgpu::device::DeviceSpec;
use crate::simgpu::occupancy::{self, KernelResources};
use crate::simgpu::perfmodel::{self, CacheSplit, StencilScenario, TileGeom};
use crate::stencil::shape::{spec, StencilSpec};

/// A fully-resolved stencil experiment (device x benchmark x precision).
#[derive(Clone, Debug)]
pub struct StencilExperiment {
    pub bench: StencilSpec,
    pub elem: usize,
    pub domain: Vec<usize>,
    pub steps: usize,
}

impl StencilExperiment {
    /// Large-domain experiment at the Table IV saturating size.
    pub fn large(dev: &DeviceSpec, bench: &str, elem: usize, steps: usize) -> Self {
        let s = spec(bench).expect("bench");
        let domain = if s.dims == 2 {
            let (x, y) = occupancy::min_domain_2d(dev, elem, s.radius);
            vec![x, y]
        } else {
            let (x, y, z) = occupancy::min_domain_3d(dev, elem, s.radius);
            vec![x, y, z]
        };
        Self { bench: s, elem, domain, steps }
    }

    /// Small-domain experiment: sized to (just) fully fit in the freed
    /// on-chip capacity — the Fig 6 strong-scaling case.
    pub fn small(dev: &DeviceSpec, bench: &str, elem: usize, steps: usize) -> Self {
        let s = spec(bench).expect("bench");
        let freed = freed_capacity(dev, &s, elem);
        let cells = (freed as f64 * 0.9 / elem as f64) as usize;
        let domain = if s.dims == 2 {
            let y = ((cells as f64).sqrt() as usize / 128).max(1) * 128;
            let x = (cells / y.max(1) / 128).max(1) * 128;
            vec![x.max(128), y]
        } else {
            let side = ((cells as f64).cbrt() as usize / 32).max(1) * 32;
            vec![side.max(32); 3]
        };
        Self { bench: s, elem, domain, steps }
    }

    pub fn cells(&self) -> f64 {
        self.domain.iter().product::<usize>() as f64
    }

    pub fn scenario(&self) -> StencilScenario {
        StencilScenario {
            cells: self.cells(),
            elem: self.elem,
            radius: self.bench.radius,
            steps: self.steps,
            kernel_smem_per_cell: 2.0, // SM-OPT baseline stages via smem
        }
    }

    pub fn tile(&self) -> TileGeom {
        if self.bench.dims == 2 {
            TileGeom::tile_2d(256, 128)
        } else {
            TileGeom::tile_3d(32)
        }
    }
}

/// Kernel resource description used for occupancy across all benchmarks:
/// registers grow with stencil order (ILP buffers), smem holds the staged
/// planes.
pub fn kernel_resources(bench: &StencilSpec, elem: usize) -> KernelResources {
    let regs = 28 + 4 * bench.radius + bench.points() / 2;
    let plane = if bench.dims == 2 {
        // one staged row-block of 256 x (2r+1) elements
        256 * (2 * bench.radius + 1) * elem
    } else {
        // staged 2D planes of 32x32 x (2r+1)
        32 * 32 * (2 * bench.radius + 1) * elem
    };
    KernelResources { threads_per_tb: 256, regs_per_thread: regs, smem_per_tb: plane }
}

/// On-chip bytes freed for caching at minimum-occupancy (TB/SMX = 1),
/// device-wide.
pub fn freed_capacity(dev: &DeviceSpec, bench: &StencilSpec, elem: usize) -> usize {
    let kr = kernel_resources(bench, elem);
    match occupancy::occupancy(dev, &kr, 1) {
        Some(occ) => occ.free_bytes_device(dev),
        None => 0,
    }
}

/// Split freed capacity per cache-location policy into a CacheSplit,
/// via the §III-B planner over the domain tiers.
pub fn cache_split(
    dev: &DeviceSpec,
    exp: &StencilExperiment,
    location: CacheLocation,
) -> CacheSplit {
    let kr = kernel_resources(&exp.bench, exp.elem);
    let occ = match occupancy::occupancy(dev, &kr, 1) {
        Some(o) => o,
        None => return CacheSplit::default(),
    };
    let sm_cap = occ.free_smem_bytes_device(dev) as f64;
    // register caching suffers the §IV-E compiler reuse inefficiency:
    // reserve ~27% of the freed registers (48 of 178 in the paper's
    // example) as unusable.
    let reg_cap = occ.free_reg_bytes_device(dev) as f64 * 0.73;
    let domain_bytes = exp.cells() * exp.elem as f64;
    // tiers: interior vs TB-boundary (perimeter rows of each tile)
    let tile = exp.tile();
    let n_tbs = (exp.cells() / tile.cells_per_tb).ceil();
    let boundary = (n_tbs * tile.perimeter_cells * exp.bench.radius as f64 * exp.elem as f64)
        .min(domain_bytes);
    let interior = domain_bytes - boundary;
    let tiers = caching::stencil_tiers(interior, boundary, 0.0);
    let plan = caching::plan(location, &tiers, sm_cap, reg_cap);
    CacheSplit { sm_bytes: plan.cached_bytes_sm(), reg_bytes: plan.cached_bytes_reg() }
}

/// One Fig 5/6 row: the speedup of the *best* cache location (the paper
/// reports the peak of sm/reg/mix).
#[derive(Clone, Debug)]
pub struct SpeedupRow {
    pub bench: &'static str,
    pub domain: Vec<usize>,
    pub best_location: CacheLocation,
    pub speedup: f64,
    pub cached_fraction: f64,
    pub projected_gcells: f64,
}

/// Evaluate one benchmark on one device (large or small domain).
pub fn speedup_row(dev: &DeviceSpec, exp: &StencilExperiment, perks_eff: f64) -> SpeedupRow {
    let scenario = exp.scenario();
    let tile = exp.tile();
    let mut best = (CacheLocation::Implicit, 0.0, CacheSplit::default());
    for loc in [CacheLocation::SharedOnly, CacheLocation::RegOnly, CacheLocation::Both] {
        let split = cache_split(dev, exp, loc);
        let s = perfmodel::speedup(dev, &scenario, &split, &tile, perks_eff);
        if s > best.1 {
            best = (loc, s, split);
        }
    }
    let (loc, speedup, split) = best;
    SpeedupRow {
        bench: exp.bench.name,
        domain: exp.domain.clone(),
        best_location: loc,
        speedup,
        cached_fraction: (split.total() / (scenario.domain_bytes())).min(1.0),
        projected_gcells: perfmodel::projected_peak(dev, &scenario, &split, &tile) / 1e9,
    }
}

/// Speedups for every cache location (Fig 8's heatmap row).
pub fn location_row(
    dev: &DeviceSpec,
    exp: &StencilExperiment,
    perks_eff: f64,
) -> Vec<(CacheLocation, f64)> {
    let scenario = exp.scenario();
    let tile = exp.tile();
    CacheLocation::all()
        .into_iter()
        .map(|loc| {
            if loc == CacheLocation::Implicit {
                // IMP: no explicit caching; persistent kernel still avoids
                // relaunch and wins L2 reuse on the halo — model as the L2
                // cacheable fraction of the domain
                let l2_frac =
                    (dev.l2_bytes as f64 / scenario.domain_bytes()).min(1.0);
                let split = CacheSplit { sm_bytes: 0.0, reg_bytes: 0.0 };
                let s_none = perfmodel::speedup(dev, &scenario, &split, &tile, perks_eff);
                // L2 hits claw back up to ~20% of the traffic time
                (loc, s_none * (1.0 + 0.25 * l2_frac))
            } else {
                let split = cache_split(dev, exp, loc);
                (loc, perfmodel::speedup(dev, &scenario, &split, &tile, perks_eff))
            }
        })
        .collect()
}

/// Model one run of `exp.steps` steps under an execution model — the
/// engine of `session::Backend::Simulated`. Uses the same Eq 5-11
/// projection as the figure renderers, plus the launch/sync constants of
/// the CG model and a nominal host link for the host-loop round trip.
pub fn modeled_run(dev: &DeviceSpec, exp: &StencilExperiment, mode: ExecMode) -> ModeledRun {
    let s = exp.scenario();
    let d = s.domain_bytes();
    let steps = exp.steps as f64;
    match mode {
        // CG-only model: every stencil entrypoint rejects it before
        // reaching here; modeled as unrunnable so no tuner selects it
        ExecMode::Pipelined => ModeledRun {
            wall_seconds: f64::INFINITY,
            invocations: 0,
            host_bytes: 0,
            barrier_wait_seconds: 0.0,
        },
        ExecMode::HostLoop => ModeledRun {
            // relaunch every step; the whole state round-trips through the
            // host on top of the device-side stream time
            wall_seconds: perfmodel::t_baseline(dev, &s, perfmodel::EFF_BASELINE)
                + steps * (T_LAUNCH + 2.0 * d / HOST_LINK_BW),
            invocations: exp.steps as u64,
            host_bytes: (2.0 * d * steps) as u64,
            barrier_wait_seconds: 0.0,
        },
        ExecMode::HostLoopResident => ModeledRun {
            // relaunch every step, but the state stays device-resident:
            // one upload + one download across the whole run
            wall_seconds: perfmodel::t_baseline(dev, &s, perfmodel::EFF_BASELINE)
                + steps * T_LAUNCH
                + 2.0 * d / HOST_LINK_BW,
            invocations: exp.steps as u64,
            host_bytes: (2.0 * d) as u64,
            barrier_wait_seconds: 0.0,
        },
        ExecMode::Persistent => {
            // best cache split over explicit locations, as speedup_row does
            let tile = exp.tile();
            let mut best_t = f64::INFINITY;
            let mut best_split = CacheSplit::default();
            for loc in [CacheLocation::SharedOnly, CacheLocation::RegOnly, CacheLocation::Both]
            {
                let split = cache_split(dev, exp, loc);
                let t = perfmodel::t_perks(dev, &s, &split, &tile);
                if t < best_t {
                    best_t = t;
                    best_split = split;
                }
            }
            let eff = if best_split.total() >= 0.85 * d {
                perfmodel::EFF_PERKS_SMALL
            } else {
                perfmodel::EFF_PERKS_LARGE
            };
            let barrier = steps * T_SYNC;
            ModeledRun {
                wall_seconds: best_t / eff + T_LAUNCH + barrier + 2.0 * d / HOST_LINK_BW,
                invocations: 1,
                host_bytes: (2.0 * d) as u64,
                barrier_wait_seconds: barrier,
            }
        }
    }
}

/// One **measured** (not modeled) CPU stencil mode from
/// [`measure_cpu_stencil_modes`] / [`measure_cpu_stencil_temporal`].
#[derive(Clone, Debug)]
pub struct MeasuredStencilMode {
    pub mode: ExecMode,
    /// Temporal-blocking degree of the pooled arm (1 = per-step exchange;
    /// always 1 for host-loop).
    pub bt: usize,
    pub wall_seconds: f64,
    /// Launches: 1 for the pooled persistent advance, `steps` host-loop.
    pub invocations: u64,
    /// OS threads spawned *during* `advance` — 0 for the stencil pool
    /// (workers spawn at `prepare`), `steps * workers` for the
    /// relaunch-per-step baseline.
    pub advance_spawns: u64,
    /// Grid-barrier sync generations *during* `advance` — the pooled arm
    /// pays `2 * ceil(steps / bt)` (+1 initial-load sync on the first
    /// run); host-loop has no grid barrier (its joins are implicit).
    pub barrier_syncs: u64,
    /// Shared-array ("global") traffic of the run.
    pub global_bytes: u64,
    /// Redundant-compute ratio (>= 1.0; the measured `OverlapCost`).
    pub redundancy: f64,
    pub cells_per_sec: f64,
}

impl MeasuredStencilMode {
    /// Barrier syncs per time step — the synchronization cost temporal
    /// blocking divides by `bt` (2/step at `bt = 1`).
    pub fn barriers_per_step(&self, steps: usize) -> f64 {
        self.barrier_syncs as f64 / steps.max(1) as f64
    }

    /// Stable BENCH-json fragment, shared by the benches that report this
    /// measurement so the schema cannot drift between them (the stencil
    /// counterpart of `MeasuredCgMode::json`).
    pub fn json(&self) -> String {
        format!(
            "{{\"mode\":\"{}\",\"bt\":{},\"wall_seconds\":{:.6},\"invocations\":{},\
             \"advance_spawns\":{},\"barrier_syncs\":{},\"global_bytes\":{},\
             \"redundancy\":{:.4}}}",
            self.mode.key(),
            self.bt,
            self.wall_seconds,
            self.invocations,
            self.advance_spawns,
            self.barrier_syncs,
            self.global_bytes,
            self.redundancy
        )
    }
}

/// Measure spawn-per-step host-loop vs spawn-once pooled persistent
/// stencil on one benchmark through the session API, snapshotting the
/// thread-spawn counter around each `advance` (the pool spawns at
/// `prepare`, so a pooled advance must read 0). One shared protocol for
/// `cpu_perks`, `e2e_modes` and `table2_concurrency`.
pub fn measure_cpu_stencil_modes(
    bench: &str,
    interior: &str,
    steps: usize,
    threads: usize,
) -> crate::error::Result<Vec<MeasuredStencilMode>> {
    measure_cpu_stencil_temporal(bench, interior, steps, threads, &[1])
}

/// [`measure_cpu_stencil_modes`] extended with the temporal-blocking
/// composition: one host-loop baseline row followed by one pooled
/// persistent row per degree in `degrees` (each a fresh session built
/// with `SessionBuilder::temporal(bt)`). Alongside wall/launches/traffic
/// it snapshots the process-wide spawn *and* barrier-sync counters
/// around each `advance`, exposing the `2 * ceil(steps / bt)` barrier
/// batching and the measured overlap redundancy — the `temporal_ablation`
/// bench's protocol. The counters are process-global: attribution is
/// exact in single-threaded bench mains, approximate under a concurrent
/// test harness.
pub fn measure_cpu_stencil_temporal(
    bench: &str,
    interior: &str,
    steps: usize,
    threads: usize,
    degrees: &[usize],
) -> crate::error::Result<Vec<MeasuredStencilMode>> {
    use crate::session::{Backend, SessionBuilder};
    let mut out = Vec::new();
    let arms = std::iter::once((ExecMode::HostLoop, 1usize))
        .chain(degrees.iter().map(|&bt| (ExecMode::Persistent, bt)));
    for (mode, bt) in arms {
        let mut s = SessionBuilder::stencil(bench, interior, "f64")
            .temporal(bt)
            .backend(Backend::cpu(threads))
            .mode(mode)
            .build()?;
        // build() already prepared the solver — the pool (persistent
        // mode) spawned its workers there, not in advance
        let spawns0 = crate::util::counters::thread_spawns();
        let syncs0 = crate::util::counters::barrier_syncs();
        s.advance(steps)?;
        let advance_spawns = crate::util::counters::thread_spawns() - spawns0;
        let barrier_syncs = crate::util::counters::barrier_syncs() - syncs0;
        let rep = s.report();
        out.push(MeasuredStencilMode {
            mode,
            bt,
            wall_seconds: rep.wall_seconds,
            invocations: rep.invocations,
            advance_spawns,
            barrier_syncs,
            global_bytes: rep.host_bytes,
            redundancy: rep.redundancy.unwrap_or(1.0),
            cells_per_sec: rep.fom,
        });
    }
    Ok(out)
}

/// The benchmark lists by dimensionality (Figs 5/6/8 group them).
pub fn benches_2d() -> Vec<&'static str> {
    vec!["2d5pt", "2ds9pt", "2d13pt", "2d17pt", "2d21pt", "2ds25pt", "2d9pt", "2d25pt"]
}

pub fn benches_3d() -> Vec<&'static str> {
    vec!["3d7pt", "3d13pt", "3d17pt", "3d27pt", "poisson"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::{a100, v100};
    use crate::util::stats::geomean;

    #[test]
    fn modeled_run_orders_modes_like_the_paper() {
        // persistent < resident < host-loop for a PERKS-favourable setup
        let dev = a100();
        let exp = StencilExperiment::large(&dev, "2d5pt", 8, 1000);
        let h = modeled_run(&dev, &exp, crate::coordinator::ExecMode::HostLoop);
        let r = modeled_run(&dev, &exp, crate::coordinator::ExecMode::HostLoopResident);
        let p = modeled_run(&dev, &exp, crate::coordinator::ExecMode::Persistent);
        assert!(p.wall_seconds < r.wall_seconds, "{} vs {}", p.wall_seconds, r.wall_seconds);
        assert!(r.wall_seconds < h.wall_seconds, "{} vs {}", r.wall_seconds, h.wall_seconds);
        // traffic accounting matches the execution models
        assert!(h.host_bytes > r.host_bytes);
        assert_eq!(r.host_bytes, p.host_bytes);
        assert_eq!(p.invocations, 1);
        assert!(p.barrier_wait_seconds > 0.0);
        assert!(h.wall_seconds.is_finite() && p.wall_seconds > 0.0);
    }

    #[test]
    fn measured_stencil_modes_contrast_launches_and_traffic() {
        // NB: `advance_spawns` reads the global spawn counter, which
        // concurrent tests may bump — benches (single-threaded mains)
        // assert on it; here we check the launch/traffic contrast and the
        // BENCH-json schema only.
        let modes = measure_cpu_stencil_modes("2d5pt", "12x12", 3, 2).unwrap();
        assert_eq!(modes.len(), 2);
        assert_eq!(modes[0].mode, ExecMode::HostLoop);
        assert_eq!(modes[1].mode, ExecMode::Persistent);
        assert_eq!(modes[0].invocations, 3, "one relaunch per step");
        assert_eq!(modes[1].invocations, 1, "one resident launch per advance");
        assert!(modes[0].global_bytes > modes[1].global_bytes);
        assert_eq!(modes[0].bt, 1);
        assert_eq!(modes[1].bt, 1);
        for m in &modes {
            let j = m.json();
            for key in [
                "\"mode\"",
                "\"bt\"",
                "\"wall_seconds\"",
                "\"invocations\"",
                "\"advance_spawns\"",
                "\"barrier_syncs\"",
                "\"global_bytes\"",
                "\"redundancy\"",
            ] {
                assert!(j.contains(key), "{j}");
            }
        }
    }

    #[test]
    fn measured_temporal_arms_report_degrees_and_redundancy() {
        let modes = measure_cpu_stencil_temporal("2d5pt", "16x16", 8, 2, &[1, 4]).unwrap();
        assert_eq!(modes.len(), 3, "host-loop + one pooled arm per degree");
        assert_eq!(modes[0].mode, ExecMode::HostLoop);
        assert_eq!((modes[1].bt, modes[2].bt), (1, 4));
        // bt=1 computes no overlap; bt=4 must report its trapezoid work
        assert_eq!(modes[1].redundancy, 1.0);
        assert!(modes[2].redundancy > 1.0, "{}", modes[2].redundancy);
        // NB: barrier_syncs reads a process-global counter, so under the
        // concurrent test harness only lower bounds are safe; the exact
        // 2*ceil(steps/bt)+1 assertion lives on the pool's own counter
        // (stencil::pool tests) and in the single-threaded bench mains.
        assert!(modes[1].barrier_syncs >= 2 * 8 + 1, "{}", modes[1].barrier_syncs);
        assert!(modes[2].barrier_syncs >= 2 * 2 + 1, "{}", modes[2].barrier_syncs);
    }

    #[test]
    fn fig5_shape_large_domains() {
        // large domains: geomean speedup > 1 and below ~3 (paper: 1.53x
        // overall; 1.58 A100-2D, 2.01 V100-2D, 1.10 A100-3D, 1.29 V100-3D)
        for dev in [a100(), v100()] {
            let sp: Vec<f64> = benches_2d()
                .iter()
                .map(|b| {
                    let e = StencilExperiment::large(&dev, b, 8, 1000);
                    speedup_row(&dev, &e, perfmodel::EFF_PERKS_LARGE).speedup
                })
                .collect();
            let g = geomean(&sp);
            assert!(g > 1.05 && g < 3.0, "{}: 2D large geomean {g}", dev.name);
        }
    }

    #[test]
    fn fig6_small_domains_beat_large() {
        // Fig 6 vs Fig 5: fully-cacheable small domains aggregate to a
        // clearly larger geomean speedup than large domains (paper: 2.48
        // vs 1.58 on A100-2D)
        for dev in [a100(), v100()] {
            let (mut large, mut small) = (Vec::new(), Vec::new());
            for b in benches_2d() {
                let l = StencilExperiment::large(&dev, b, 4, 1000);
                let s = StencilExperiment::small(&dev, b, 4, 1000);
                large.push(speedup_row(&dev, &l, perfmodel::EFF_PERKS_LARGE).speedup);
                small.push(speedup_row(&dev, &s, perfmodel::EFF_PERKS_SMALL).speedup);
            }
            let (gl, gs) = (geomean(&large), geomean(&small));
            assert!(gs > gl, "{}: small {gs} should beat large {gl}", dev.name);
        }
    }

    #[test]
    fn small_domains_fully_cached() {
        let dev = a100();
        for b in ["2d5pt", "2d9pt", "3d7pt"] {
            let e = StencilExperiment::small(&dev, b, 4, 1000);
            let row = speedup_row(&dev, &e, perfmodel::EFF_PERKS_SMALL);
            assert!(row.cached_fraction > 0.85, "{b}: {}", row.cached_fraction);
        }
    }

    #[test]
    fn fig8_both_usually_best_but_not_always() {
        let dev = a100();
        let e = StencilExperiment::large(&dev, "2d5pt", 4, 1000);
        let rows = location_row(&dev, &e, perfmodel::EFF_PERKS_LARGE);
        let both = rows.iter().find(|(l, _)| *l == CacheLocation::Both).unwrap().1;
        let sm = rows.iter().find(|(l, _)| *l == CacheLocation::SharedOnly).unwrap().1;
        assert!(both >= sm, "BTH {both} should beat SM {sm} for low-order");
    }

    #[test]
    fn v100_speedup_competitive_with_a100_generation_gap() {
        // §VI-F: PERKS on V100 recovers ~ a hardware generation
        let a = a100();
        let v = v100();
        let sp_v: Vec<f64> = benches_2d()
            .iter()
            .chain(benches_3d().iter())
            .map(|b| {
                let e = StencilExperiment::large(&v, b, 8, 1000);
                speedup_row(&v, &e, perfmodel::EFF_PERKS_LARGE).speedup
            })
            .collect();
        let gen_gap = a.gmem_bw / v.gmem_bw; // 1.73x
        let g = geomean(&sp_v);
        assert!(
            g > 0.5 * gen_gap,
            "V100 PERKS geomean {g} not comparable to generation gap {gen_gap}"
        );
    }
}
