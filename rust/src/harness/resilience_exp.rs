//! Resilience experiment builders: checkpoint-cadence overhead sweeps
//! and injected-fault recovery arms over the farm runtime — the
//! measurement protocol behind `benches/resilience.rs` and the
//! `BENCH_resilience.json` gate. Two invariants are *asserted* here, not
//! just reported: clean runs recover zero times, and a recovered run's
//! final state is bit-identical to an uninjected one.
//!
//! The durable sweeps ([`stencil_durable_sweep`] / [`cg_durable_sweep`])
//! repeat the cadence sweep with crash-consistent snapshot persistence
//! enabled (`ResilienceConfig::durable`), asserting two more invariants
//! before reporting a single number: cadence 0 commits **zero** durable
//! frames (durability off the cadence path costs nothing), and enabling
//! the write-out never changes the solution bits. `bench_check` gates
//! the reported rows (`durable` = 1): clean durable arms restore zero
//! times and the default cadence stays within 10% wall of its cadence-0
//! reference.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::farm::SolverFarm;
use crate::runtime::resilience::{FaultPlan, ResilienceConfig, RetryPolicy};
use crate::sparse::gen;
use crate::spmv::merge::MergePlan;
use crate::stencil::{self, Domain};
use crate::util::counters;

/// One arm of the resilience sweep: a workload run at one checkpoint
/// cadence (clean), or one seeded-fault recovery run (`injected > 0`).
///
/// `wall_seconds` is the min-over-reps wall of a single command (the
/// overhead-gate number); the counters are totals over the whole arm.
#[derive(Clone, Debug)]
pub struct ResilienceRow {
    /// Workload label (`stencil-2d5pt`, `cg-poisson`, ...).
    pub case: String,
    /// Checkpoint cadence in epochs (0 = cadence checkpoints off).
    pub cadence: u64,
    pub wall_seconds: f64,
    /// Supervised recoveries performed — **must be 0 when `injected`
    /// is 0** (`bench_check` gates on it).
    pub recoveries: u64,
    /// Epochs re-executed by those recoveries.
    pub replayed_epochs: u64,
    /// Bytes copied into resident-state checkpoints.
    pub checkpoint_bytes: u64,
    /// Faults the installed plan held (0 on clean arms).
    pub injected: u64,
    /// Whether this arm persisted checkpoints to a durable snapshot
    /// directory (`ResilienceConfig::durable`).
    pub durable: bool,
    /// Durable frames committed by this arm's farm — **must be 0 at
    /// cadence 0** (`bench_check` gates on it; asserted here first).
    pub durable_frames: u64,
    /// Checkpoint payload bytes handed to the durable write-out.
    pub durable_bytes: u64,
    /// Snapshot restores observed during the arm (process-wide counter
    /// delta) — clean arms never restore.
    pub restores: u64,
}

impl ResilienceRow {
    /// Stable BENCH-json fragment (the resilience counterpart of
    /// `FarmSweepRow::json`).
    pub fn json(&self) -> String {
        format!(
            "{{\"case\":\"{}\",\"cadence\":{},\"wall_seconds\":{:.6},\
             \"recoveries\":{},\"replayed_epochs\":{},\
             \"checkpoint_bytes\":{},\"injected\":{},\"durable\":{},\
             \"durable_frames\":{},\"durable_bytes\":{},\"restores\":{}}}",
            self.case,
            self.cadence,
            self.wall_seconds,
            self.recoveries,
            self.replayed_epochs,
            self.checkpoint_bytes,
            self.injected,
            self.durable as u64,
            self.durable_frames,
            self.durable_bytes,
            self.restores
        )
    }
}

/// Measure the checkpoint-overhead curve for a farm stencil tenant: one
/// row per cadence, each running `reps` commands of `steps` steps on a
/// fresh farm of `workers` residents. The first cadence (conventionally
/// 0 — checkpoints off) is the reference arm; every other cadence's
/// final state must match it bit-for-bit, and every arm must report
/// zero recoveries — checkpointing is observation, not perturbation.
pub fn stencil_cadence_sweep(
    bench: &str,
    interior: &str,
    steps: usize,
    bt: usize,
    workers: usize,
    cadences: &[u64],
    reps: usize,
) -> Result<Vec<ResilienceRow>> {
    let spec = stencil::spec(bench)
        .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
    let dims = crate::session::parse_interior(interior)?;
    if cadences.is_empty() || reps == 0 {
        return Err(Error::invalid("cadences and reps must be non-empty"));
    }
    let mut d = Domain::for_spec(&spec, &dims)?;
    d.randomize(100);

    let mut rows = Vec::with_capacity(cadences.len());
    let mut reference: Option<Vec<f64>> = None;
    for &cadence in cadences {
        let farm = SolverFarm::spawn(workers)?;
        farm.install_faults(FaultPlan::new()); // hermetic: override any env plan
        let mut tenant = farm.handle().admit_stencil(&spec, &d, workers, bt)?;
        tenant.configure_resilience(ResilienceConfig::disabled().every(cadence))?;
        let mut wall = f64::INFINITY;
        let (mut recoveries, mut replayed, mut ck_bytes) = (0u64, 0u64, 0u64);
        for _ in 0..reps {
            let t0 = Instant::now();
            let run = tenant.advance(steps, None)?;
            wall = wall.min(t0.elapsed().as_secs_f64());
            recoveries += run.recoveries;
            replayed += run.replayed_epochs;
            ck_bytes += run.checkpoint_bytes;
        }
        let state = tenant.state()?;
        drop(tenant);
        drop(farm);
        match &reference {
            None => reference = Some(state),
            Some(want) if *want != state => {
                return Err(Error::Solver(format!(
                    "cadence {cadence} changed the stencil result (bit-identity broken)"
                )));
            }
            Some(_) => {}
        }
        if recoveries != 0 {
            return Err(Error::Solver(format!(
                "clean stencil arm at cadence {cadence} recovered {recoveries} times"
            )));
        }
        rows.push(ResilienceRow {
            case: format!("stencil-{bench}"),
            cadence,
            wall_seconds: wall,
            recoveries,
            replayed_epochs: replayed,
            checkpoint_bytes: ck_bytes,
            injected: 0,
            durable: false,
            durable_frames: 0,
            durable_bytes: 0,
            restores: 0,
        });
    }
    Ok(rows)
}

/// The CG twin of [`stencil_cadence_sweep`]: a `grid`×`grid` Poisson
/// system solved for `iters` fixed iterations per command. State
/// round-trips through the caller, so every rep restarts from the same
/// x/r/p — identical work per command at every cadence.
pub fn cg_cadence_sweep(
    grid: usize,
    iters: usize,
    workers: usize,
    cadences: &[u64],
    reps: usize,
) -> Result<Vec<ResilienceRow>> {
    if cadences.is_empty() || reps == 0 {
        return Err(Error::invalid("cadences and reps must be non-empty"));
    }
    let a = Arc::new(gen::poisson2d(grid));
    let b = gen::rhs(a.n_rows, 7);
    let plan = MergePlan::new(&a, workers);
    let rr0: f64 = b.iter().map(|v| v * v).sum();

    let mut rows = Vec::with_capacity(cadences.len());
    let mut reference: Option<Vec<f64>> = None;
    for &cadence in cadences {
        let farm = SolverFarm::spawn(workers)?;
        farm.install_faults(FaultPlan::new()); // hermetic: override any env plan
        let mut tenant = farm.handle().admit_cg(a.clone(), plan.clone())?;
        tenant.configure_resilience(ResilienceConfig::disabled().every(cadence))?;
        let mut wall = f64::INFINITY;
        let (mut recoveries, mut replayed, mut ck_bytes) = (0u64, 0u64, 0u64);
        let mut x = vec![0.0; a.n_rows];
        for _ in 0..reps {
            x.iter_mut().for_each(|v| *v = 0.0);
            let mut r = b.clone();
            let mut p = b.clone();
            let t0 = Instant::now();
            let run = tenant.run(&mut x, &mut r, &mut p, rr0, 0.0, iters)?;
            wall = wall.min(t0.elapsed().as_secs_f64());
            if let Some(msg) = run.error {
                return Err(Error::Solver(msg));
            }
            recoveries += run.recoveries;
            replayed += run.replayed_epochs;
            ck_bytes += run.checkpoint_bytes;
        }
        drop(tenant);
        drop(farm);
        match &reference {
            None => reference = Some(x),
            Some(want) if *want != x => {
                return Err(Error::Solver(format!(
                    "cadence {cadence} changed the CG iterates (bit-identity broken)"
                )));
            }
            Some(_) => {}
        }
        if recoveries != 0 {
            return Err(Error::Solver(format!(
                "clean CG arm at cadence {cadence} recovered {recoveries} times"
            )));
        }
        rows.push(ResilienceRow {
            case: "cg-poisson".into(),
            cadence,
            wall_seconds: wall,
            recoveries,
            replayed_epochs: replayed,
            checkpoint_bytes: ck_bytes,
            injected: 0,
            durable: false,
            durable_frames: 0,
            durable_bytes: 0,
            restores: 0,
        });
    }
    Ok(rows)
}

/// The durable arm of [`stencil_cadence_sweep`]: the same cadence sweep
/// with every checkpoint additionally persisted crash-consistently
/// under `dir` (one subdirectory per cadence, so generations never mix
/// across arms). Durable write-out happens off the scheduler lock, so
/// the farm is shut down — joining the workers and draining any
/// in-flight write — before its frame counters are read. Asserted
/// before any row is returned: bit-identity across every cadence,
/// zero recoveries, zero frames at cadence 0, and at least one frame
/// per command at every nonzero cadence.
#[allow(clippy::too_many_arguments)]
pub fn stencil_durable_sweep(
    bench: &str,
    interior: &str,
    steps: usize,
    bt: usize,
    workers: usize,
    cadences: &[u64],
    reps: usize,
    dir: &Path,
) -> Result<Vec<ResilienceRow>> {
    let spec = stencil::spec(bench)
        .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
    let dims = crate::session::parse_interior(interior)?;
    if cadences.is_empty() || reps == 0 {
        return Err(Error::invalid("cadences and reps must be non-empty"));
    }
    let mut d = Domain::for_spec(&spec, &dims)?;
    d.randomize(100);

    let mut rows = Vec::with_capacity(cadences.len());
    let mut reference: Option<Vec<f64>> = None;
    for &cadence in cadences {
        let restores_before = counters::restores();
        let mut farm = SolverFarm::spawn(workers)?;
        farm.install_faults(FaultPlan::new()); // hermetic: override any env plan
        let mut tenant = farm.handle().admit_stencil(&spec, &d, workers, bt)?;
        tenant.configure_resilience(
            ResilienceConfig::disabled()
                .every(cadence)
                .durable(dir.join(format!("cad{cadence}"))),
        )?;
        let mut wall = f64::INFINITY;
        let (mut recoveries, mut replayed, mut ck_bytes) = (0u64, 0u64, 0u64);
        for _ in 0..reps {
            let t0 = Instant::now();
            let run = tenant.advance(steps, None)?;
            wall = wall.min(t0.elapsed().as_secs_f64());
            recoveries += run.recoveries;
            replayed += run.replayed_epochs;
            ck_bytes += run.checkpoint_bytes;
        }
        let state = tenant.state()?;
        drop(tenant);
        farm.shutdown(); // join workers: every claimed frame is on disk
        let m = farm.metrics();
        drop(farm);
        match &reference {
            None => reference = Some(state),
            Some(want) if *want != state => {
                return Err(Error::Solver(format!(
                    "durable cadence {cadence} changed the stencil result (bit-identity broken)"
                )));
            }
            Some(_) => {}
        }
        if recoveries != 0 {
            return Err(Error::Solver(format!(
                "clean durable stencil arm at cadence {cadence} recovered {recoveries} times"
            )));
        }
        if cadence == 0 && m.durable_frames != 0 {
            return Err(Error::Solver(format!(
                "cadence-0 durable stencil arm committed {} frames (must be 0)",
                m.durable_frames
            )));
        }
        if cadence > 0 && steps.div_ceil(bt.max(1)) as u64 >= cadence && m.durable_frames == 0 {
            return Err(Error::Solver(format!(
                "durable stencil arm at cadence {cadence} committed no frames"
            )));
        }
        rows.push(ResilienceRow {
            case: format!("stencil-{bench}"),
            cadence,
            wall_seconds: wall,
            recoveries,
            replayed_epochs: replayed,
            checkpoint_bytes: ck_bytes,
            injected: 0,
            durable: true,
            durable_frames: m.durable_frames,
            durable_bytes: m.durable_bytes,
            restores: counters::restores().saturating_sub(restores_before),
        });
    }
    Ok(rows)
}

/// The CG twin of [`stencil_durable_sweep`]: the [`cg_cadence_sweep`]
/// workload with crash-consistent persistence enabled, under the same
/// asserted invariants.
pub fn cg_durable_sweep(
    grid: usize,
    iters: usize,
    workers: usize,
    cadences: &[u64],
    reps: usize,
    dir: &Path,
) -> Result<Vec<ResilienceRow>> {
    if cadences.is_empty() || reps == 0 {
        return Err(Error::invalid("cadences and reps must be non-empty"));
    }
    let a = Arc::new(gen::poisson2d(grid));
    let b = gen::rhs(a.n_rows, 7);
    let plan = MergePlan::new(&a, workers);
    let rr0: f64 = b.iter().map(|v| v * v).sum();

    let mut rows = Vec::with_capacity(cadences.len());
    let mut reference: Option<Vec<f64>> = None;
    for &cadence in cadences {
        let restores_before = counters::restores();
        let mut farm = SolverFarm::spawn(workers)?;
        farm.install_faults(FaultPlan::new()); // hermetic: override any env plan
        let mut tenant = farm.handle().admit_cg(a.clone(), plan.clone())?;
        tenant.configure_resilience(
            ResilienceConfig::disabled()
                .every(cadence)
                .durable(dir.join(format!("cad{cadence}"))),
        )?;
        let mut wall = f64::INFINITY;
        let (mut recoveries, mut replayed, mut ck_bytes) = (0u64, 0u64, 0u64);
        let mut x = vec![0.0; a.n_rows];
        for _ in 0..reps {
            x.iter_mut().for_each(|v| *v = 0.0);
            let mut r = b.clone();
            let mut p = b.clone();
            let t0 = Instant::now();
            let run = tenant.run(&mut x, &mut r, &mut p, rr0, 0.0, iters)?;
            wall = wall.min(t0.elapsed().as_secs_f64());
            if let Some(msg) = run.error {
                return Err(Error::Solver(msg));
            }
            recoveries += run.recoveries;
            replayed += run.replayed_epochs;
            ck_bytes += run.checkpoint_bytes;
        }
        drop(tenant);
        farm.shutdown(); // join workers: every claimed frame is on disk
        let m = farm.metrics();
        drop(farm);
        match &reference {
            None => reference = Some(x.clone()),
            Some(want) if *want != x => {
                return Err(Error::Solver(format!(
                    "durable cadence {cadence} changed the CG iterates (bit-identity broken)"
                )));
            }
            Some(_) => {}
        }
        if recoveries != 0 {
            return Err(Error::Solver(format!(
                "clean durable CG arm at cadence {cadence} recovered {recoveries} times"
            )));
        }
        if cadence == 0 && m.durable_frames != 0 {
            return Err(Error::Solver(format!(
                "cadence-0 durable CG arm committed {} frames (must be 0)",
                m.durable_frames
            )));
        }
        if cadence > 0 && iters as u64 >= cadence && m.durable_frames == 0 {
            return Err(Error::Solver(format!(
                "durable CG arm at cadence {cadence} committed no frames"
            )));
        }
        rows.push(ResilienceRow {
            case: "cg-poisson".into(),
            cadence,
            wall_seconds: wall,
            recoveries,
            replayed_epochs: replayed,
            checkpoint_bytes: ck_bytes,
            injected: 0,
            durable: true,
            durable_frames: m.durable_frames,
            durable_bytes: m.durable_bytes,
            restores: counters::restores().saturating_sub(restores_before),
        });
    }
    Ok(rows)
}

/// Shared resilience shape of the recovery arms: cadence-4 checkpoints
/// with two replay attempts — tight enough that replays stay short,
/// loose enough that recovery is exercised from a *cadence* checkpoint
/// (not just the command-entry one) for most fault epochs.
fn recovery_cfg() -> ResilienceConfig {
    ResilienceConfig::disabled().every(4).with_retry(RetryPolicy::attempts(2))
}

/// Run a farm stencil command with one seeded fault (panic or NaN at a
/// random epoch/shard — [`FaultPlan::seeded`]) under the recovery
/// config, and assert the recovered run lands bit-identically on the
/// clean run's state. The returned row reports the *faulted* arm's wall
/// and counters with `injected = 1`.
///
/// Residual tracking is forced (an unreachable tolerance) so NaN
/// poisoning is detected at the next epoch fold — the same guard
/// production tolerance-tracked runs rely on.
pub fn stencil_recovery_row(
    bench: &str,
    interior: &str,
    steps: usize,
    bt: usize,
    workers: usize,
    seed: u64,
) -> Result<ResilienceRow> {
    let spec = stencil::spec(bench)
        .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
    let dims = crate::session::parse_interior(interior)?;
    let mut d = Domain::for_spec(&spec, &dims)?;
    d.randomize(200 + seed);
    let never = Some(-1.0); // residual >= 0 never reaches it: track, don't stop

    // clean arm: same config, empty plan — the bit-identity reference
    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(FaultPlan::new());
    let mut tenant = farm.handle().admit_stencil(&spec, &d, workers, bt)?;
    tenant.configure_resilience(recovery_cfg())?;
    let clean_run = tenant.advance(steps, never)?;
    let want = tenant.state()?;
    drop(tenant);
    drop(farm);
    if clean_run.recoveries != 0 {
        return Err(Error::Solver("clean stencil arm recovered".into()));
    }

    // faulted arm: one seeded panic/NaN somewhere in the schedule
    let epochs = (steps.div_ceil(bt.max(1))) as u64;
    let plan = FaultPlan::seeded(seed, epochs, workers);
    let injected = plan.len() as u64;
    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(plan);
    let mut tenant = farm.handle().admit_stencil(&spec, &d, workers, bt)?;
    tenant.configure_resilience(recovery_cfg())?;
    let t0 = Instant::now();
    let run = tenant.advance(steps, never)?;
    let wall = t0.elapsed().as_secs_f64();
    let got = tenant.state()?;
    drop(tenant);
    drop(farm);

    if run.recoveries == 0 {
        return Err(Error::Solver(format!(
            "seeded stencil fault (seed {seed}) never triggered a recovery"
        )));
    }
    if got != want {
        return Err(Error::Solver(format!(
            "stencil recovery diverged from the clean run (seed {seed})"
        )));
    }
    Ok(ResilienceRow {
        case: format!("stencil-{bench}-recovery"),
        cadence: recovery_cfg().checkpoint_every,
        wall_seconds: wall,
        recoveries: run.recoveries,
        replayed_epochs: run.replayed_epochs,
        checkpoint_bytes: run.checkpoint_bytes,
        injected,
        durable: false,
        durable_frames: 0,
        durable_bytes: 0,
        restores: 0,
    })
}

/// The CG twin of [`stencil_recovery_row`]: one seeded fault in a
/// fixed-iteration Poisson solve, recovered and checked bit-identical
/// (x, r, p and the recurrence scalar all compared).
pub fn cg_recovery_row(
    grid: usize,
    iters: usize,
    workers: usize,
    seed: u64,
) -> Result<ResilienceRow> {
    let a = Arc::new(gen::poisson2d(grid));
    let b = gen::rhs(a.n_rows, 300 + seed);
    let plan = MergePlan::new(&a, workers);
    let rr0: f64 = b.iter().map(|v| v * v).sum();
    let fresh = |x: &mut Vec<f64>, r: &mut Vec<f64>, p: &mut Vec<f64>| {
        x.iter_mut().for_each(|v| *v = 0.0);
        r.copy_from_slice(&b);
        p.copy_from_slice(&b);
    };

    // clean arm
    let (mut x, mut r, mut p) = (vec![0.0; a.n_rows], b.clone(), b.clone());
    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(FaultPlan::new());
    let mut tenant = farm.handle().admit_cg(a.clone(), plan.clone())?;
    tenant.configure_resilience(recovery_cfg())?;
    let clean = tenant.run(&mut x, &mut r, &mut p, rr0, 0.0, iters)?;
    drop(tenant);
    drop(farm);
    if let Some(msg) = clean.error {
        return Err(Error::Solver(msg));
    }
    if clean.recoveries != 0 {
        return Err(Error::Solver("clean CG arm recovered".into()));
    }
    let (want_x, want_r, want_p, want_rr) = (x.clone(), r.clone(), p.clone(), clean.rr);

    // faulted arm
    let fplan = FaultPlan::seeded(seed, iters as u64, workers);
    let injected = fplan.len() as u64;
    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(fplan);
    let mut tenant = farm.handle().admit_cg(a.clone(), plan.clone())?;
    tenant.configure_resilience(recovery_cfg())?;
    fresh(&mut x, &mut r, &mut p);
    let t0 = Instant::now();
    let run = tenant.run(&mut x, &mut r, &mut p, rr0, 0.0, iters)?;
    let wall = t0.elapsed().as_secs_f64();
    drop(tenant);
    drop(farm);
    if let Some(msg) = run.error {
        return Err(Error::Solver(msg));
    }

    if run.recoveries == 0 {
        return Err(Error::Solver(format!(
            "seeded CG fault (seed {seed}) never triggered a recovery"
        )));
    }
    if x != want_x || r != want_r || p != want_p || run.rr.to_bits() != want_rr.to_bits() {
        return Err(Error::Solver(format!(
            "CG recovery diverged from the clean run (seed {seed})"
        )));
    }
    Ok(ResilienceRow {
        case: "cg-poisson-recovery".into(),
        cadence: recovery_cfg().checkpoint_every,
        wall_seconds: wall,
        recoveries: run.recoveries,
        replayed_epochs: run.replayed_epochs,
        checkpoint_bytes: run.checkpoint_bytes,
        injected,
        durable: false,
        durable_frames: 0,
        durable_bytes: 0,
        restores: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_sweeps_are_clean_and_serialize() {
        let rows = stencil_cadence_sweep("2d5pt", "12x12", 8, 1, 2, &[0, 2], 1).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.recoveries == 0 && r.injected == 0));
        assert_eq!(rows[0].checkpoint_bytes, 0, "cadence 0 must not checkpoint");
        assert!(rows[1].checkpoint_bytes > 0, "cadence 2 must checkpoint");
        let j = rows[1].json();
        for key in [
            "\"case\"",
            "\"cadence\"",
            "\"wall_seconds\"",
            "\"recoveries\"",
            "\"replayed_epochs\"",
            "\"checkpoint_bytes\"",
            "\"injected\"",
        ] {
            assert!(j.contains(key), "{j}");
        }

        let rows = cg_cadence_sweep(8, 6, 2, &[0, 2], 1).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.recoveries == 0));
        assert!(rows[1].checkpoint_bytes > 0);
    }

    #[test]
    fn durable_sweeps_write_frames_and_stay_bit_identical() {
        let dir = std::env::temp_dir()
            .join(format!("perks-durable-harness-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let rows =
            stencil_durable_sweep("2d5pt", "12x12", 8, 1, 2, &[0, 2], 1, &dir.join("st"))
                .unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.durable && r.recoveries == 0 && r.injected == 0));
        assert_eq!(rows[0].durable_frames, 0, "cadence 0 must commit no durable frames");
        assert_eq!(rows[0].durable_bytes, 0);
        assert!(rows[1].durable_frames >= 1, "cadence 2 must commit durable frames");
        assert!(rows[1].durable_bytes > 0);
        let j = rows[1].json();
        for key in ["\"durable\":1", "\"durable_frames\"", "\"durable_bytes\"", "\"restores\""] {
            assert!(j.contains(key), "{j}");
        }

        let rows = cg_durable_sweep(8, 6, 2, &[0, 2], 1, &dir.join("cg")).unwrap();
        assert_eq!(rows[0].durable_frames, 0);
        assert!(rows[1].durable_frames >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_rows_recover_bit_identically() {
        let row = stencil_recovery_row("2d5pt", "12x12", 12, 1, 2, 3).unwrap();
        assert!(row.recoveries >= 1);
        assert_eq!(row.injected, 1);
        let row = cg_recovery_row(8, 8, 2, 5).unwrap();
        assert!(row.recoveries >= 1);
        assert_eq!(row.injected, 1);
    }

    #[test]
    fn sweeps_reject_bad_configs() {
        assert!(stencil_cadence_sweep("17d99pt", "8x8", 4, 1, 1, &[0], 1).is_err());
        assert!(stencil_cadence_sweep("2d5pt", "8x8", 4, 1, 1, &[], 1).is_err());
        assert!(stencil_cadence_sweep("2d5pt", "8x8", 4, 1, 1, &[0], 0).is_err());
        assert!(cg_cadence_sweep(8, 4, 1, &[], 1).is_err());
        assert!(cg_cadence_sweep(8, 4, 1, &[0], 0).is_err());
    }
}
