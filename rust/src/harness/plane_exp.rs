//! Submission-plane experiment builders: the 10k-tenant async stress
//! protocol behind the `plane_stress` bench and the `BENCH_plane.json`
//! schema (shared so bench and CI gate cannot drift).
//!
//! The shape under test is the serving claim of the plane: *thousands*
//! of concurrent tenants driven by one or two front-end OS threads
//! (each a [`LocalExecutor`] multiplexing per-tenant async tasks), every
//! advance submitted as a batched [`CommandGraph`] — so enqueue-side
//! scheduler-lock acquisitions scale with *batches*, not epochs, which
//! the row's `sched_lock_acquisitions == plane_batches` invariant (and
//! `bench_check`) asserts. Bit-identity against a solo pool is verified
//! for tenant 0 before any number is reported.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::farm::SolverFarm;
use crate::runtime::plane::{CommandGraph, LocalExecutor, PlaneConfig};
use crate::stencil::pool::StencilPool;
use crate::stencil::{self, Domain};
use crate::util::counters;
use crate::util::stats::finite_rate;

/// One configuration row of the async-plane stress protocol.
#[derive(Clone, Debug)]
pub struct PlaneStressRow {
    /// Concurrent tenants admitted to the shared farm.
    pub tenants: usize,
    /// Front-end OS threads driving the tenants (each one executor).
    pub frontend_threads: usize,
    /// Farm worker threads.
    pub workers: usize,
    /// Graph-batched commands per tenant.
    pub rounds: usize,
    /// Epoch-chain segments per command graph.
    pub segments: usize,
    /// Completed solves (`tenants * rounds`).
    pub solves: usize,
    pub wall_seconds: f64,
    pub solves_per_sec: f64,
    /// Plane batches enqueued during the measured region.
    pub plane_batches: u64,
    /// Enqueue-side scheduler-lock acquisitions — must equal
    /// `plane_batches` (the batched-path invariant).
    pub sched_lock_acquisitions: u64,
    /// Admission-control rejections — must be 0 under healthy load.
    pub plane_sheds: u64,
    /// Admission timeouts — must be 0 under healthy load.
    pub plane_timeouts: u64,
    /// Peak concurrently held plane slots (sustained in-flight
    /// concurrency across the tenant fleet).
    pub inflight_peak: usize,
    /// Solver-substrate OS threads spawned during admit + drive — **0**
    /// is the acceptance bar (front-end threads are the harness's own
    /// and are not counted; exact in single-threaded bench mains).
    pub admission_spawns: u64,
}

impl PlaneStressRow {
    /// Stable BENCH-json fragment (the plane counterpart of
    /// [`super::farm_exp::FarmSweepRow::json`]).
    pub fn json(&self) -> String {
        format!(
            "{{\"tenants\":{},\"frontend_threads\":{},\"workers\":{},\
             \"rounds\":{},\"segments\":{},\"solves\":{},\
             \"wall_seconds\":{:.6},\"solves_per_sec\":{:.3},\
             \"plane_batches\":{},\"sched_lock_acquisitions\":{},\
             \"plane_sheds\":{},\"plane_timeouts\":{},\
             \"inflight_peak\":{},\"admission_spawns\":{}}}",
            self.tenants,
            self.frontend_threads,
            self.workers,
            self.rounds,
            self.segments,
            self.solves,
            self.wall_seconds,
            self.solves_per_sec,
            self.plane_batches,
            self.sched_lock_acquisitions,
            self.plane_sheds,
            self.plane_timeouts,
            self.inflight_peak,
            self.admission_spawns
        )
    }
}

/// Drive `tenants` concurrent stencil sessions through the async
/// submission plane on `frontend_threads` OS threads (each a
/// [`LocalExecutor`] multiplexing its share of per-tenant async tasks)
/// over a farm of `workers` resident threads.
///
/// Each tenant performs `rounds` commands; each command is a batched
/// [`CommandGraph`] of `segments` segments of `steps` steps. Tenant 0's
/// final state is verified bit-identical to a solo [`StencilPool`]
/// advancing the same seeded domain by the same total steps — the async
/// plane, the graph batching, and the multiplexing must all be invisible
/// to the bits.
#[allow(clippy::too_many_arguments)]
pub fn plane_stress(
    bench: &str,
    interior: &str,
    steps: usize,
    segments: usize,
    rounds: usize,
    workers: usize,
    tenants: usize,
    frontend_threads: usize,
) -> Result<PlaneStressRow> {
    let spec = stencil::spec(bench)
        .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
    let dims = crate::session::parse_interior(interior)?;
    if tenants == 0 || rounds == 0 || steps == 0 || segments == 0 || frontend_threads == 0 {
        return Err(Error::invalid(
            "tenants, rounds, steps, segments and frontend_threads must be > 0",
        ));
    }
    let graph = CommandGraph::schedule(steps * segments, steps, None)?;
    let farm = SolverFarm::spawn_with(workers, PlaneConfig::default())?;
    let handle = farm.handle();
    let spawns0 = counters::thread_spawns();

    // admit every tenant (1 band shard each: serving-scale sessions are
    // small; the farm's workers provide the parallelism across tenants)
    let mut sessions = Vec::with_capacity(tenants);
    for t in 0..tenants {
        let mut d = Domain::for_spec(&spec, &dims)?;
        d.randomize(500 + t as u64);
        sessions.push(Some(handle.admit_stencil(&spec, &d, 1, 1)?));
    }
    // reference domain for the bit-identity check (same seed as tenant 0)
    let mut d0 = Domain::for_spec(&spec, &dims)?;
    d0.randomize(500);

    // partition tenants round-robin across the front-end threads; each
    // thread drives its share on one LocalExecutor
    let mut chunks: Vec<Vec<(usize, crate::runtime::farm::FarmStencil)>> =
        (0..frontend_threads).map(|_| Vec::new()).collect();
    for (i, s) in sessions.iter_mut().enumerate() {
        chunks[i % frontend_threads].push((i, s.take().expect("admitted above")));
    }

    let t0 = Instant::now();
    let graph_ref = &graph;
    let state0 = std::thread::scope(|scope| -> Result<Vec<f64>> {
        let mut joins = Vec::with_capacity(frontend_threads);
        for chunk in chunks {
            joins.push(scope.spawn(move || -> Result<Option<Vec<f64>>> {
                let ex = LocalExecutor::new();
                let results: Vec<Result<Option<Vec<f64>>>> = ex.run(async {
                    let mut handles = Vec::with_capacity(chunk.len());
                    for (i, mut s) in chunk {
                        // spawned tasks are 'static: each owns its graph
                        let graph = graph_ref.clone();
                        handles.push(ex.spawn(async move {
                            for _ in 0..rounds {
                                s.advance_graph_async(&graph).await?;
                            }
                            // harvest tenant 0's bits before the session
                            // drops (drop releases the tenant)
                            if i == 0 { s.state().map(Some) } else { Ok(None) }
                        }));
                    }
                    let mut out = Vec::with_capacity(handles.len());
                    for h in handles {
                        out.push(h.await);
                    }
                    out
                });
                let mut state0 = None;
                for r in results {
                    if let Some(st) = r? {
                        state0 = Some(st);
                    }
                }
                Ok(state0)
            }));
        }
        let mut state0 = None;
        for j in joins {
            let got = j.join().map_err(|_| Error::Solver("front-end thread panicked".into()))??;
            if let Some(st) = got {
                state0 = Some(st);
            }
        }
        state0.ok_or_else(|| Error::Solver("tenant 0 produced no state".into()))
    })?;
    let wall_seconds = t0.elapsed().as_secs_f64();
    let admission_spawns = counters::thread_spawns() - spawns0;
    let m = farm.metrics();

    // the whole point: async + graphs + multiplexing are bit-invisible
    let mut solo = StencilPool::spawn(&spec, &d0, 1)?;
    solo.run(steps * segments * rounds, None)?;
    if state0 != solo.state() {
        return Err(Error::Solver(
            "async-plane tenant diverged from its solo-pool run (bit-identity broken)".into(),
        ));
    }

    let solves = tenants * rounds;
    Ok(PlaneStressRow {
        tenants,
        frontend_threads,
        workers,
        rounds,
        segments,
        solves,
        wall_seconds,
        solves_per_sec: finite_rate(solves as f64, wall_seconds),
        plane_batches: m.plane_batches,
        sched_lock_acquisitions: m.sched_lock_acquisitions,
        plane_sheds: m.plane_sheds,
        plane_timeouts: m.plane_timeouts,
        inflight_peak: m.plane_inflight_peak,
        admission_spawns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stress_row_measures_batching_and_serializes() {
        // 12 tenants, 2 front-end threads, 3-segment graphs, 2 rounds
        let row = plane_stress("2d5pt", "10x10", 2, 3, 2, 2, 12, 2).unwrap();
        assert_eq!(row.tenants, 12);
        assert_eq!(row.solves, 24);
        assert!(row.wall_seconds > 0.0 && row.solves_per_sec > 0.0);
        // the batched-path invariant: one lock acquisition per batch,
        // segment chaining pays zero extra
        assert_eq!(row.plane_batches, 24, "one batch per graph submission");
        assert_eq!(row.sched_lock_acquisitions, row.plane_batches);
        assert_eq!(row.plane_sheds, 0);
        assert_eq!(row.plane_timeouts, 0);
        // every batch holds `segments` slots until harvested
        assert!(row.inflight_peak >= 3 && row.inflight_peak <= 12 * 3, "{}", row.inflight_peak);
        let j = row.json();
        for key in [
            "\"tenants\"",
            "\"frontend_threads\"",
            "\"workers\"",
            "\"rounds\"",
            "\"segments\"",
            "\"solves\"",
            "\"wall_seconds\"",
            "\"solves_per_sec\"",
            "\"plane_batches\"",
            "\"sched_lock_acquisitions\"",
            "\"plane_sheds\"",
            "\"plane_timeouts\"",
            "\"inflight_peak\"",
            "\"admission_spawns\"",
        ] {
            assert!(j.contains(key), "{j}");
        }
    }

    #[test]
    fn stress_rejects_bad_configs() {
        assert!(plane_stress("17d99pt", "8x8", 1, 1, 1, 1, 1, 1).is_err());
        assert!(plane_stress("2d5pt", "8x8", 0, 1, 1, 1, 1, 1).is_err());
        assert!(plane_stress("2d5pt", "8x8", 1, 0, 1, 1, 1, 1).is_err());
        assert!(plane_stress("2d5pt", "8x8", 1, 1, 0, 1, 1, 1).is_err());
        assert!(plane_stress("2d5pt", "8x8", 1, 1, 1, 1, 0, 1).is_err());
        assert!(plane_stress("2d5pt", "8x8", 1, 1, 1, 1, 1, 0).is_err());
    }
}
