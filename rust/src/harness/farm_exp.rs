//! Farm experiment builders: measure the multi-tenant
//! [`crate::runtime::farm::SolverFarm`] against the pool-per-session
//! baseline — the Table II concurrency argument at serving scale. One
//! shared protocol for `farm_throughput` and `table2_concurrency`, so
//! their numbers (and the `BENCH_farm.json` schema) cannot drift.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::farm::SolverFarm;
use crate::stencil::pool::StencilPool;
use crate::stencil::{self, Domain};
use crate::util::counters;
use crate::util::stats::{finite_rate, percentile};

/// One tenant-count row of the farm-vs-pool-per-session sweep.
///
/// *Throughput* is solves/second over the whole arm (a solve = one
/// `advance(steps)` command); *latency* is per-solve submit→complete wall
/// (for the farm arm this includes queueing — the p99 under load is the
/// serving metric); *queue* is the farm's enqueue→first-dispatch wait;
/// *fairness* is the farm's max/mean queue-wait ratio.
#[derive(Clone, Debug)]
pub struct FarmSweepRow {
    pub tenants: usize,
    /// Total solves per arm (`tenants * rounds`).
    pub solves: usize,
    pub farm_wall: f64,
    pub solo_wall: f64,
    pub farm_solves_per_sec: f64,
    pub solo_solves_per_sec: f64,
    /// `solo_wall / farm_wall` (> 1 means the shared farm wins).
    pub speedup: f64,
    pub farm_p50_ms: f64,
    pub farm_p99_ms: f64,
    pub solo_p50_ms: f64,
    pub solo_p99_ms: f64,
    pub queue_p50_ms: f64,
    pub queue_p99_ms: f64,
    pub fairness: f64,
    /// OS threads spawned during admissions + advances of the farm arm —
    /// **0** is the multi-tenant acceptance bar (exact in single-threaded
    /// bench mains; the per-farm `spawn_count` is the test-safe mirror).
    pub admission_spawns: u64,
}

impl FarmSweepRow {
    /// Stable BENCH-json fragment shared by every bench that reports this
    /// measurement (the farm counterpart of `MeasuredStencilMode::json`).
    pub fn json(&self) -> String {
        format!(
            "{{\"tenants\":{},\"solves\":{},\"farm_wall_seconds\":{:.6},\
             \"solo_wall_seconds\":{:.6},\"farm_solves_per_sec\":{:.3},\
             \"solo_solves_per_sec\":{:.3},\"speedup\":{:.4},\
             \"farm_p50_ms\":{:.4},\"farm_p99_ms\":{:.4},\
             \"solo_p50_ms\":{:.4},\"solo_p99_ms\":{:.4},\
             \"queue_p50_ms\":{:.4},\"queue_p99_ms\":{:.4},\
             \"fairness\":{:.3},\"admission_spawns\":{}}}",
            self.tenants,
            self.solves,
            self.farm_wall,
            self.solo_wall,
            self.farm_solves_per_sec,
            self.solo_solves_per_sec,
            self.speedup,
            self.farm_p50_ms,
            self.farm_p99_ms,
            self.solo_p50_ms,
            self.solo_p99_ms,
            self.queue_p50_ms,
            self.queue_p99_ms,
            self.fairness,
            self.admission_spawns
        )
    }
}

/// Measure `tenants` concurrent small stencil sessions on one shared
/// farm of `workers` resident threads against the pool-per-session
/// baseline (each session builds — and tears down — its own
/// `StencilPool` of the same `workers` threads, the per-session
/// launch/teardown cost the farm amortizes away).
///
/// The farm arm enqueues every session's `advance(steps)` before waiting
/// on any (true concurrent multi-tenant load through the submission
/// queue); the baseline serializes sessions the way independent solo
/// pools on one machine would. Both arms advance identical seeded
/// domains for `rounds` commands, and the first tenant's final state is
/// verified bit-identical across arms before any number is reported.
pub fn farm_vs_pool_per_session(
    bench: &str,
    interior: &str,
    steps: usize,
    rounds: usize,
    workers: usize,
    tenants: usize,
) -> Result<FarmSweepRow> {
    let spec = stencil::spec(bench)
        .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
    let dims = crate::session::parse_interior(interior)?;
    if tenants == 0 || rounds == 0 {
        return Err(Error::invalid("tenants and rounds must be > 0"));
    }
    let doms: Vec<Domain> = (0..tenants)
        .map(|t| {
            let mut d = Domain::for_spec(&spec, &dims)?;
            d.randomize(100 + t as u64);
            Ok(d)
        })
        .collect::<Result<_>>()?;

    // ---- farm arm: one resident worker set, all sessions admitted ----
    let farm = SolverFarm::spawn(workers)?;
    let spawns0 = counters::thread_spawns();
    let handle = farm.handle();
    let mut sessions = Vec::with_capacity(tenants);
    for d in &doms {
        sessions.push(handle.admit_stencil(&spec, d, workers, 1)?);
    }
    let mut farm_lat = Vec::with_capacity(tenants * rounds);
    let t_farm = Instant::now();
    for _ in 0..rounds {
        // enqueue everything, then wait: concurrent tenants in flight
        let mut starts = Vec::with_capacity(tenants);
        for s in sessions.iter_mut() {
            starts.push(Instant::now());
            s.submit(steps, None)?;
        }
        for (s, t0) in sessions.iter_mut().zip(&starts) {
            s.wait()?;
            farm_lat.push(t0.elapsed().as_secs_f64());
        }
    }
    let farm_wall = t_farm.elapsed().as_secs_f64();
    let admission_spawns = counters::thread_spawns() - spawns0;
    let farm_state0 = sessions[0].state()?;
    let metrics = farm.metrics();
    drop(sessions);
    drop(farm);

    // ---- baseline: a fresh pool per session, sessions serialized ----
    let mut solo_lat = Vec::with_capacity(tenants * rounds);
    let mut solo_state0 = Vec::new();
    let t_solo = Instant::now();
    for (i, d) in doms.iter().enumerate() {
        let mut pool = StencilPool::spawn(&spec, d, workers)?;
        for _ in 0..rounds {
            let t0 = Instant::now();
            pool.run(steps, None)?;
            solo_lat.push(t0.elapsed().as_secs_f64());
        }
        if i == 0 {
            solo_state0 = pool.state();
        }
        // teardown inside the timed region: it is part of the
        // pool-per-session cost the farm amortizes
        drop(pool);
    }
    let solo_wall = t_solo.elapsed().as_secs_f64();

    if farm_state0 != solo_state0 {
        return Err(Error::Solver(
            "farm tenant diverged from its solo-pool run (bit-identity broken)".into(),
        ));
    }

    let solves = tenants * rounds;
    Ok(FarmSweepRow {
        tenants,
        solves,
        farm_wall,
        solo_wall,
        farm_solves_per_sec: finite_rate(solves as f64, farm_wall),
        solo_solves_per_sec: finite_rate(solves as f64, solo_wall),
        speedup: solo_wall / farm_wall.max(crate::util::stats::MIN_WALL_SECONDS),
        farm_p50_ms: percentile(&farm_lat, 50.0) * 1e3,
        farm_p99_ms: percentile(&farm_lat, 99.0) * 1e3,
        solo_p50_ms: percentile(&solo_lat, 50.0) * 1e3,
        solo_p99_ms: percentile(&solo_lat, 99.0) * 1e3,
        queue_p50_ms: metrics.queue_wait_p50 * 1e3,
        queue_p99_ms: metrics.queue_wait_p99 * 1e3,
        fairness: metrics.fairness(),
        admission_spawns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_row_measures_and_serializes() {
        let row = farm_vs_pool_per_session("2d5pt", "12x12", 2, 1, 2, 2).unwrap();
        assert_eq!(row.tenants, 2);
        assert_eq!(row.solves, 2);
        assert!(row.farm_wall > 0.0 && row.solo_wall > 0.0);
        assert!(row.farm_solves_per_sec > 0.0 && row.speedup > 0.0);
        assert!(row.farm_p99_ms >= row.farm_p50_ms);
        assert!(row.fairness >= 1.0);
        // NB: admission_spawns reads the process-global spawn counter,
        // exact only in single-threaded bench mains — not asserted here.
        let j = row.json();
        for key in [
            "\"tenants\"",
            "\"solves\"",
            "\"farm_wall_seconds\"",
            "\"solo_wall_seconds\"",
            "\"farm_solves_per_sec\"",
            "\"solo_solves_per_sec\"",
            "\"speedup\"",
            "\"farm_p50_ms\"",
            "\"farm_p99_ms\"",
            "\"solo_p50_ms\"",
            "\"solo_p99_ms\"",
            "\"queue_p50_ms\"",
            "\"queue_p99_ms\"",
            "\"fairness\"",
            "\"admission_spawns\"",
        ] {
            assert!(j.contains(key), "{j}");
        }
    }

    #[test]
    fn sweep_rejects_bad_configs() {
        assert!(farm_vs_pool_per_session("17d99pt", "8x8", 1, 1, 1, 1).is_err());
        assert!(farm_vs_pool_per_session("2d5pt", "8xbad", 1, 1, 1, 1).is_err());
        assert!(farm_vs_pool_per_session("2d5pt", "8x8", 1, 0, 1, 1).is_err());
        assert!(farm_vs_pool_per_session("2d5pt", "8x8", 1, 1, 1, 0).is_err());
    }
}
