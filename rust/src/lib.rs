//! # perks — Persistent Kernels for Iterative Memory-bound Applications
//!
//! A full reproduction of the PERKS execution model (Zhang et al.) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas stencil + fused CG kernels,
//!   with the PERKS variant keeping the domain resident in VMEM across an
//!   in-kernel time loop.
//! * **L2** (`python/compile/model.py`): JAX solver graphs, AOT-lowered to
//!   HLO text once (`make artifacts`).
//! * **L3** (this crate): the execution-model runtime (host-loop vs
//!   persistent), the caching policy engine, the GPU memory-hierarchy
//!   simulator that regenerates the paper's figures, and the substrates the
//!   paper depends on (stencil benchmarks, sparse matrices, merge-based
//!   SpMV, a CG solver).
//!
//! See DESIGN.md for the architecture and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod cg;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod harness;
pub mod runtime;
pub mod simgpu;
pub mod sparse;
pub mod spmv;
pub mod stencil;
pub mod util;

pub use error::{Error, Result};
