//! # perks — Persistent Kernels for Iterative Memory-bound Applications
//!
//! A full reproduction of the PERKS execution model (Zhang et al.) as a
//! three-layer Rust + JAX + Pallas stack. The paper's idea: instead of
//! relaunching a kernel every time step (round-tripping all state through
//! global memory), launch *once*, keep the state resident in on-chip
//! memory across an in-kernel time loop, and synchronize with grid-wide
//! barriers — turning the unused register/shared-memory capacity of
//! low-occupancy memory-bound kernels into a cache.
//!
//! ## Start here: [`session`]
//!
//! The public API is the [`session`] module. Entry is one of two *typed
//! sub-builders* — [`SessionBuilder::stencil`] or [`SessionBuilder::cg`]
//! — so solver-specific knobs are compile-time scoped (`temporal` exists
//! only on stencil sessions; `preconditioner`/`pipelined` only on CG
//! sessions), while shared knobs (backend, mode/policy, farm, durable,
//! resilience, threads) live on both. `build()` yields a [`Session`]
//! driving a backend-agnostic [`Solver`] with a unified
//! [`session::Report`]:
//!
//! ```no_run
//! use perks::session::{Backend, ExecMode, SessionBuilder};
//! use perks::runtime::Runtime;
//!
//! let rt = Runtime::new(Runtime::default_dir())?;
//! let mut session = SessionBuilder::stencil("2d5pt", "128x128", "f32")
//!     .backend(Backend::pjrt(rt))
//!     .mode(ExecMode::Persistent)
//!     .build()?;
//! let report = session.run(64)?;
//! println!("{:.2e} {}", report.fom, report.fom_unit);
//! # Ok::<(), perks::Error>(())
//! ```
//!
//! A CG session, pipelined and preconditioned (one grid-barrier
//! reduction per iteration instead of classic CG's two — [`cg::pipeline`]):
//!
//! ```
//! use perks::session::{Preconditioner, SessionBuilder};
//!
//! let mut session = SessionBuilder::cg(1 << 10)
//!     .pipelined(true)
//!     .preconditioner(Preconditioner::Jacobi)
//!     .threads(4)
//!     .build()?;
//! let report = session.run(200)?;
//! assert!(report.residual.unwrap() >= 0.0);
//! # Ok::<(), perks::Error>(())
//! ```
//!
//! Three backends plug into the same seam:
//!
//! * `Backend::Pjrt` — AOT-lowered HLO artifacts (built once by
//!   `python/compile/aot.py`, see below) executed through the PJRT CPU
//!   client: the measured cross-language path;
//! * `Backend::CpuPersistent` — a persistent-threads CPU substrate that
//!   demonstrates the PERKS model *physically* (OS threads as thread
//!   blocks, thread-local slabs as the on-chip cache, a grid barrier as
//!   `grid.sync()`; for CG, a spawn-once worker pool with the iteration
//!   loop resident in the workers and barrier-reduced dot products —
//!   [`cg::pool`]);
//! * `Backend::Simulated` — the paper's analytical performance model
//!   (Eqs 5-13) on the Table I device catalog, regenerating the paper's
//!   figures at A100/V100 scale.
//!
//! ## Serving at scale: [`runtime::farm`]
//!
//! The same launch/teardown-amortization argument that puts the time
//! loop inside a persistent kernel says a service handling millions of
//! small solves must not build a worker pool per session. The
//! multi-tenant [`runtime::farm::SolverFarm`] spawns one resident worker
//! set per *farm* and admits many concurrent sessions — mixed 2D/3D
//! stencils at any temporal degree, and CG — onto it:
//! `SessionBuilder::farm(&farm)` routes an ordinary session through the
//! farm's submission queue (band-sharded within a session, round-robin
//! with an age-based fairness bound across sessions), with per-session
//! state resident between epochs, zero thread spawns per admission, and
//! iterates bit-identical to the solo-pool session at every farm worker
//! count. `benches/farm_throughput.rs` measures the farm against
//! pool-per-session and feeds the CI perf-regression gate
//! (`bin/bench_check` vs `bench/baselines/`).
//!
//! ## Surviving process death: durable checkpoints
//!
//! A farm session built with `SessionBuilder::durable(dir)` commits
//! every epoch-boundary checkpoint crash-consistently to disk
//! ([`runtime::resilience::snapshot::SnapshotStore`]: tmp write + fsync
//! + atomic rename into generation-numbered, checksummed frames — off
//! the scheduler lock, so the hot loop never blocks on I/O). After a
//! SIGKILL-class death the `perks_recover` binary (or
//! [`SnapshotStore::restore`](runtime::resilience::snapshot::SnapshotStore::restore)
//! plus `FarmStencil::restore_from` / `Checkpoint::cg_state`) rebuilds
//! each tenant from the self-describing frames and resumes
//! **bit-identically** to the uninterrupted run; torn or corrupt frames
//! fall back one generation instead of failing. The on-disk format,
//! crash-consistency argument, and operator walkthrough live in
//! `docs/RECOVERY.md`; `benches/resilience.rs` gates the write-out
//! overhead.
//!
//! ## Layers
//!
//! * **L1** (`python/compile/kernels/`): Pallas stencil + fused CG kernels,
//!   with the PERKS variant keeping the domain resident in VMEM across an
//!   in-kernel time loop.
//! * **L2** (`python/compile/model.py`): JAX solver graphs, AOT-lowered to
//!   HLO text once (`make artifacts`).
//! * **L3** (this crate): [`session`] on top of the execution-model
//!   runtime ([`coordinator`]), the caching policy engine, the GPU
//!   memory-hierarchy simulator ([`simgpu`]), and the substrates the paper
//!   depends on ([`stencil`] benchmarks, [`sparse`] matrices, merge-based
//!   [`spmv`], a [`cg`] solver).
//!
//! ## Invariants and their gates
//!
//! The hand-rolled synchronization above (parked condvars, slot-ordered
//! barrier folds, countdown transitions, zero-alloc hot loops) is held
//! together by named invariants, catalogued in `docs/INVARIANTS.md` and
//! enforced three ways: statically by [`lint`] (`bin/perks_lint`, a
//! blocking CI step), dynamically by `util::counters` asserts, and at
//! the perf level by `bin/bench_check` against `bench/baselines/`.
//!
//! See DESIGN.md for the architecture and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod cg;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod harness;
pub mod lint;
pub mod runtime;
pub mod session;
pub mod simgpu;
pub mod sparse;
pub mod spmv;
pub mod stencil;
pub mod util;

pub use error::{Error, Result};
pub use session::{
    Backend, CgSessionBuilder, ExecMode, ExecPolicy, Preconditioner, Session, SessionBuilder,
    Solver, StencilSessionBuilder, Workload,
};
