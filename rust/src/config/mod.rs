//! Experiment configuration system.
//!
//! No TOML/serde crates are available offline, so `parser` implements the
//! small configuration dialect we need (sections, scalars, lists) from
//! scratch, and `experiment` maps parsed values onto typed experiment
//! descriptions used by the CLI and the bench harness.

pub mod experiment;
pub mod parser;

pub use experiment::{ExperimentConfig, StencilJob};
pub use parser::{Config, Value};
