//! Typed experiment descriptions parsed from config files.
//!
//! The `perks` CLI and the bench harness both consume these; an example
//! config lives at `examples/configs/quickstart.toml`.

use crate::config::parser::Config;
use crate::coordinator::ExecMode;
use crate::error::{Error, Result};

/// One stencil run job.
#[derive(Clone, Debug)]
pub struct StencilJob {
    pub bench: String,
    pub interior: String,
    pub dtype: String,
    pub steps: usize,
    pub modes: Vec<ExecMode>,
    pub repeats: usize,
}

impl StencilJob {
    pub fn from_config(cfg: &Config, section: &str) -> Result<Self> {
        let modes_raw = cfg.str_or(section, "modes", "all");
        let modes = parse_modes(&modes_raw)?;
        Ok(Self {
            bench: cfg.str_or(section, "bench", "2d5pt"),
            interior: cfg.str_or(section, "interior", "128x128"),
            dtype: cfg.str_or(section, "dtype", "f32"),
            steps: cfg.int_or(section, "steps", 64) as usize,
            modes,
            repeats: cfg.int_or(section, "repeats", 3) as usize,
        })
    }
}

/// Parse a mode list like "host-loop,persistent" or "all". These are
/// stencil experiment configs, so "all" means the three paper modes —
/// `Pipelined` is CG-only and must be named explicitly (stencil drivers
/// reject it with a clear error).
pub fn parse_modes(s: &str) -> Result<Vec<ExecMode>> {
    if s == "all" {
        return Ok(vec![ExecMode::HostLoop, ExecMode::HostLoopResident, ExecMode::Persistent]);
    }
    s.split(',')
        .map(|m| match m.trim() {
            "host-loop" => Ok(ExecMode::HostLoop),
            "host-loop-resident" | "resident" => Ok(ExecMode::HostLoopResident),
            "persistent" | "perks" => Ok(ExecMode::Persistent),
            "pipelined" | "pipe" => Ok(ExecMode::Pipelined),
            other => Err(Error::Config(format!("unknown mode {other:?}"))),
        })
        .collect()
}

/// Top-level experiment config: which GPU to simulate, artifact dir, jobs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub device: String,
    pub artifact_dir: String,
    pub stencil_jobs: Vec<StencilJob>,
}

impl ExperimentConfig {
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let device = cfg.str_or("", "device", "A100");
        let artifact_dir = cfg.str_or("", "artifacts", "artifacts");
        let mut stencil_jobs = Vec::new();
        for section in cfg.sections() {
            if section.starts_with("stencil") && !section.is_empty() {
                stencil_jobs.push(StencilJob::from_config(cfg, section)?);
            }
        }
        Ok(Self { device, artifact_dir, stencil_jobs })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_config(&Config::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_experiment() {
        let text = r#"
            device = "V100"
            artifacts = "artifacts"
            [stencil.a]
            bench = "2d9pt"
            steps = 32
            modes = "host-loop,persistent"
            [stencil.b]
            interior = "64x64"
            dtype = "f64"
        "#;
        let cfg = Config::parse(text).unwrap();
        let exp = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(exp.device, "V100");
        assert_eq!(exp.stencil_jobs.len(), 2);
        let a = &exp.stencil_jobs[0];
        assert_eq!(a.bench, "2d9pt");
        assert_eq!(a.steps, 32);
        assert_eq!(a.modes, vec![ExecMode::HostLoop, ExecMode::Persistent]);
        let b = &exp.stencil_jobs[1];
        assert_eq!(b.dtype, "f64");
        assert_eq!(b.modes.len(), 3);
    }

    #[test]
    fn bad_mode_rejected() {
        assert!(parse_modes("warp-speed").is_err());
        assert_eq!(parse_modes("all").unwrap().len(), 3);
    }
}
