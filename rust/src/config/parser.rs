//! Minimal TOML-subset configuration parser (built from scratch; no serde
//! in the vendored dependency set).
//!
//! Supported syntax:
//!
//! ```toml
//! # comment
//! [section]
//! key = "string"
//! steps = 1000
//! ratio = 0.5
//! flag = true
//! sizes = [128, 256, 512]
//! names = ["2d5pt", "2d9pt"]
//! ```

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => Err(Error::Config(format!("expected int, got {other:?}"))),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => Err(Error::Config(format!("expected float, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_list(&self) -> Result<&[Value]> {
        match self {
            Value::List(v) => Ok(v),
            other => Err(Error::Config(format!("expected list, got {other:?}"))),
        }
    }

    fn parse_scalar(tok: &str) -> Result<Value> {
        let tok = tok.trim();
        if tok.starts_with('"') && tok.ends_with('"') && tok.len() >= 2 {
            return Ok(Value::Str(tok[1..tok.len() - 1].to_string()));
        }
        match tok {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = tok.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(Error::Config(format!("cannot parse value {tok:?}")))
    }

    fn parse(tok: &str) -> Result<Value> {
        let tok = tok.trim();
        if tok.starts_with('[') {
            if !tok.ends_with(']') {
                return Err(Error::Config(format!("unterminated list {tok:?}")));
            }
            let inner = tok[1..tok.len() - 1].trim();
            if inner.is_empty() {
                return Ok(Value::List(vec![]));
            }
            let items = split_top_level(inner)?
                .into_iter()
                .map(|s| Value::parse_scalar(&s))
                .collect::<Result<Vec<_>>>()?;
            return Ok(Value::List(items));
        }
        Value::parse_scalar(tok)
    }
}

/// Split a list body on commas (no nested lists supported — flat only).
fn split_top_level(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err(Error::Config(format!("unterminated string in {s:?}")));
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    Ok(out)
}

/// Parsed configuration: `section -> key -> value`. Keys outside any
/// section land in the "" section.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!("line {}: bad section", lineno + 1)));
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), Value::parse(v)?);
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn require(&self, section: &str, key: &str) -> Result<&Value> {
        self.get(section, key)
            .ok_or_else(|| Error::Config(format!("missing [{section}] {key}")))
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }

    /// String with default.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str().ok())
            .unwrap_or(default)
            .to_string()
    }

    /// Integer with default.
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int().ok()).unwrap_or(default)
    }

    /// Float with default.
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # top comment
        title = "perks"
        [stencil]
        bench = "2d5pt"   # inline comment
        steps = 1000
        ratio = 0.5
        cache = true
        sizes = [128, 256]
        names = ["a", "b"]
        [empty]
    "#;

    #[test]
    fn parses_all_value_kinds() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("", "title").unwrap().as_str().unwrap(), "perks");
        assert_eq!(c.get("stencil", "bench").unwrap().as_str().unwrap(), "2d5pt");
        assert_eq!(c.get("stencil", "steps").unwrap().as_int().unwrap(), 1000);
        assert_eq!(c.get("stencil", "ratio").unwrap().as_float().unwrap(), 0.5);
        assert!(c.get("stencil", "cache").unwrap().as_bool().unwrap());
        let sizes = c.get("stencil", "sizes").unwrap().as_list().unwrap();
        assert_eq!(sizes, &[Value::Int(128), Value::Int(256)]);
        let names = c.get("stencil", "names").unwrap().as_list().unwrap();
        assert_eq!(names[1].as_str().unwrap(), "b");
    }

    #[test]
    fn int_coerces_to_float() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.get("", "x").unwrap().as_float().unwrap(), 3.0);
    }

    #[test]
    fn defaults() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.str_or("s", "k", "d"), "d");
        assert_eq!(c.int_or("s", "k", 7), 7);
        assert_eq!(c.float_or("s", "k", 0.25), 0.25);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = [1, 2").is_err());
        assert!(Config::parse("x = @garbage").is_err());
    }

    #[test]
    fn require_missing() {
        let c = Config::parse("").unwrap();
        assert!(c.require("a", "b").is_err());
    }
}
