//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the perks library.
#[derive(Error, Debug)]
pub enum Error {
    /// Error from the XLA / PJRT runtime layer.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// Filesystem / IO error (artifact loading, config files, traces).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed artifact manifest (see `runtime::manifest`).
    #[error("manifest: {0}")]
    Manifest(String),

    /// Configuration parse / validation error.
    #[error("config: {0}")]
    Config(String),

    /// Shape or dtype mismatch between host data and an artifact signature.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Solver-level failure (divergence, non-SPD matrix, ...).
    #[error("solver: {0}")]
    Solver(String),

    /// Invalid argument to a library call.
    #[error("invalid argument: {0}")]
    Invalid(String),

    /// Submission rejected by the plane's admission control (bounded
    /// queue full under the `Shed` policy, or a batch larger than the
    /// configured caps). The command was **not** enqueued; retrying
    /// later is safe. Counted by `util::counters::plane_sheds`.
    #[error("shed: {0}")]
    Shed(String),

    /// Submission timed out waiting for a plane slot (`Timeout`
    /// admission policy). The command was **not** enqueued. Counted by
    /// `util::counters::plane_timeouts`.
    #[error("timeout: {0}")]
    Timeout(String),
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand for `Error::Invalid` with a formatted message.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::Invalid(msg.into())
    }
}
