//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the perks library.
#[derive(Error, Debug)]
pub enum Error {
    /// Error from the XLA / PJRT runtime layer.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// Filesystem / IO error (artifact loading, config files, traces).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed artifact manifest (see `runtime::manifest`).
    #[error("manifest: {0}")]
    Manifest(String),

    /// Configuration parse / validation error.
    #[error("config: {0}")]
    Config(String),

    /// Shape or dtype mismatch between host data and an artifact signature.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Solver-level failure (divergence, non-SPD matrix, ...).
    #[error("solver: {0}")]
    Solver(String),

    /// Invalid argument to a library call.
    #[error("invalid argument: {0}")]
    Invalid(String),

    /// Submission rejected by the plane's admission control (bounded
    /// queue full under the `Shed` policy, or a batch larger than the
    /// configured caps). The command was **not** enqueued; retrying
    /// later is safe. Counted by `util::counters::plane_sheds`.
    #[error("shed: {0}")]
    Shed(String),

    /// Submission timed out waiting for a plane slot (`Timeout`
    /// admission policy). The command was **not** enqueued. Counted by
    /// `util::counters::plane_timeouts`.
    #[error("timeout: {0}")]
    Timeout(String),

    /// A farm worker panicked (or an injected panic fault fired) while
    /// running one shard of a command, with the exact (phase, shard,
    /// epoch) coordinate attached so supervisors can classify and
    /// replay without string matching. Retryable: a
    /// `runtime::resilience::RetryPolicy` restores the last checkpoint
    /// and replays instead of surfacing this. Counted by
    /// `util::counters::farm_recoveries` when recovered.
    #[error("fault: worker panicked at phase {phase}, shard {shard}, epoch {epoch}")]
    Fault {
        /// Phase constant of the failing engine (`runtime::farm::P_*`).
        phase: usize,
        /// Shard index within the phase.
        shard: usize,
        /// The tenant's lifetime epoch counter at the failure.
        epoch: u64,
    },

    /// A durable snapshot frame failed to decode, verify, or restore:
    /// a torn write, a checksum mismatch, an unmanifested or missing
    /// generation, or a snapshot directory with no restorable frame at
    /// all. Structured — the snapshot store classifies and falls back a
    /// generation on its own; this surfaces only when no generation
    /// survives (or a durable write-out itself fails). Not retryable:
    /// the bytes on disk will not change on their own.
    #[error("snapshot: {0}")]
    Snapshot(String),

    /// A blocking wait's watchdog deadline expired while the command was
    /// still in flight (`runtime::resilience::ResilienceConfig::
    /// deadline`). The command keeps draining; releasing the session
    /// reaps it as a zombie through the farm's release path.
    #[error("stuck: command exceeded {waited_ms} ms deadline (phase {phase}, epoch {epoch})")]
    Stuck {
        /// Phase the command was in when the deadline expired.
        phase: usize,
        /// The tenant's lifetime epoch counter at expiry.
        epoch: u64,
        /// The deadline that was exceeded, in milliseconds.
        waited_ms: u64,
    },
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand for `Error::Invalid` with a formatted message.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::Invalid(msg.into())
    }

    /// Would retrying the failed operation plausibly succeed? True for
    /// transient scheduling/fault classes (a panicked shard, a stuck
    /// command, admission backpressure), false for deterministic
    /// input/configuration/solver errors, where a replay would fail
    /// identically. This is the classification the farm's
    /// `RetryPolicy` uses to decide checkpoint-restore-replay.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            Error::Fault { .. } | Error::Stuck { .. } | Error::Shed(_) | Error::Timeout(_)
        )
    }
}
