//! Human-readable formatting + a minimal fixed-width ASCII table writer
//! used by the bench harness to print the paper's tables/figures as text.

/// Format a byte count with binary units ("11.2 MiB").
pub fn bytes(n: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n;
    let mut u = 0;
    while v.abs() >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a cell-update rate as GCells/s (the paper's stencil FOM).
pub fn gcells(cells_per_sec: f64) -> String {
    format!("{:.2} GCells/s", cells_per_sec / 1e9)
}

/// Format a bandwidth as GB/s (decimal, as GPU datasheets do).
pub fn gbps(bytes_per_sec: f64) -> String {
    format!("{:.1} GB/s", bytes_per_sec / 1e9)
}

/// Format seconds adaptively (ns/us/ms/s).
pub fn secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Fixed-width ASCII table builder.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render with column auto-widths; header separated by dashes.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512.0), "512 B");
        assert_eq!(bytes(2048.0), "2.00 KiB");
        assert_eq!(bytes(11.2 * 1024.0 * 1024.0), "11.20 MiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(secs(2e-9), "2.0 ns");
        assert_eq!(secs(3.5e-5), "35.00 us");
        assert_eq!(secs(0.012), "12.00 ms");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_str(&["a", "1"]).row_str(&["longer", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        Table::new(&["a", "b"]).row_str(&["only-one"]);
    }
}
