//! Minimal JSON parsing for the `BENCH_*.json` artifacts.
//!
//! The bench emitters hand-format a small, fixed schema (objects, arrays,
//! strings, finite numbers, booleans, null) and the vendored dependency
//! set carries no serde — so the perf-regression gate
//! (`tools: bench_check`) parses with this recursive-descent reader
//! instead. It accepts exactly the JSON the emitters produce plus
//! ordinary whitespace, and rejects trailing garbage; it is not a
//! general-purpose JSON library (no surrogate-pair decoding, no
//! number-precision guarantees beyond `f64`).

use crate::error::{Error, Result};

/// A parsed JSON value. Object keys keep emission order (the gate
/// compares by lookup, never by index).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one JSON document (rejects trailing non-whitespace).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(Error::invalid(format!(
                "json: trailing garbage at byte {pos}"
            )));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    ///
    /// Duplicate keys are kept as parsed (emission order); lookup
    /// returns the **first** occurrence. The bench emitters never
    /// duplicate keys, so this is a documented tie-break for hand-edited
    /// artifacts, not a schema feature.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(Error::invalid(format!(
            "json: expected {:?} at byte {}",
            c as char, *pos
        )))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(Error::invalid("json: unexpected end of input")),
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(Error::invalid(format!("json: bad literal at byte {}", *pos)))
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        fields.push((key, val));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(Error::invalid(format!("json: expected ',' or '}}' at byte {}", *pos))),
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(Error::invalid(format!("json: expected ',' or ']' at byte {}", *pos))),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while let Some(&c) = b.get(*pos) {
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let esc = b.get(*pos).copied().ok_or_else(|| {
                    Error::invalid("json: unterminated escape")
                })?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| Error::invalid("json: bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| Error::invalid("json: bad \\u escape"))?;
                        *pos += 4;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(Error::invalid("json: unknown escape")),
                }
            }
            _ => {
                // multi-byte UTF-8: copy the full sequence through
                let start = *pos - 1;
                let len = utf8_len(c);
                let end = start + len;
                let s = b
                    .get(start..end)
                    .and_then(|seg| std::str::from_utf8(seg).ok())
                    .ok_or_else(|| Error::invalid("json: invalid utf-8 in string"))?;
                out.push_str(s);
                *pos = end;
            }
        }
    }
    Err(Error::invalid("json: unterminated string"))
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).expect("ascii number run");
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| Error::invalid(format!("json: bad number {s:?} at byte {start}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_bench_schema_shapes() {
        let doc = r#"{"bench":"farm","steps":8,"rows":[{"tenants":1,"speedup":1.53,"ok":true,"none":null}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("farm"));
        assert_eq!(v.get("steps").unwrap().as_u64(), Some(8));
        let rows = v.get("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("tenants").unwrap().as_u64(), Some(1));
        assert!((rows[0].get("speedup").unwrap().as_f64().unwrap() - 1.53).abs() < 1e-12);
        assert_eq!(rows[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(rows[0].get("none"), Some(&Json::Null));
    }

    #[test]
    fn parses_numbers_strings_and_nesting() {
        let v = Json::parse(" [ -1.5e3 , \"a\\\"b\\n\" , [] , {} ] ").unwrap();
        let items = v.as_array().unwrap();
        assert_eq!(items[0].as_f64(), Some(-1500.0));
        assert_eq!(items[1].as_str(), Some("a\"b\n"));
        assert_eq!(items[2], Json::Arr(Vec::new()));
        assert_eq!(items[3], Json::Obj(Vec::new()));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\":1} trailing",
            "\"unterminated",
            "{\"a\" 1}",
            "nope",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn truncated_documents_error_without_panicking() {
        // every prefix of a real artifact line must produce Err, never a
        // panic — bench_check reads whatever half-written file CI left
        let doc = r#"{"bench":"farm","rows":[{"tenants":1,"ok":true}]}"#;
        for cut in 1..doc.len() {
            let prefix = &doc[..cut];
            assert!(Json::parse(prefix).is_err(), "truncated {prefix:?} should fail");
        }
    }

    #[test]
    fn bare_nan_and_infinity_are_rejected() {
        // Rust's f64 parser would happily read "NaN"/"inf"; the number
        // scanner must never hand them to it (JSON has no such literals)
        for bad in ["NaN", "nan", "Infinity", "-Infinity", "inf", "-inf",
                    r#"{"wall_seconds":NaN}"#, "[1,Infinity]"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn duplicate_keys_keep_first_occurrence() {
        let v = Json::parse(r#"{"a":1,"a":2,"b":3}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1), "lookup is first-wins");
        assert_eq!(v.get("b").unwrap().as_u64(), Some(3));
        // both fields are preserved in parse order
        assert_eq!(v, Json::Obj(vec![
            ("a".into(), Json::Num(1.0)),
            ("a".into(), Json::Num(2.0)),
            ("b".into(), Json::Num(3.0)),
        ]));
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Str("3".into()).as_u64(), None);
    }

    #[test]
    fn roundtrips_a_real_emitter_fragment() {
        // exactly the shape MeasuredStencilMode::json produces
        let doc = "{\"mode\":\"persistent\",\"bt\":4,\"wall_seconds\":0.001234,\
                   \"invocations\":1,\"advance_spawns\":0,\"barrier_syncs\":5,\
                   \"global_bytes\":123456,\"redundancy\":1.1250}";
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("mode").unwrap().as_str(), Some("persistent"));
        assert_eq!(v.get("advance_spawns").unwrap().as_u64(), Some(0));
        assert_eq!(v.get("barrier_syncs").unwrap().as_u64(), Some(5));
    }
}
