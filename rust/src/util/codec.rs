//! Dependency-free little-endian binary codec for durable snapshot
//! payloads (`runtime::resilience::snapshot`): slab/vector state is
//! serialized as raw `f64::to_bits` words — never through text — so a
//! persisted checkpoint restores **bit-identical** floats, NaN payloads
//! and signed zeros included.
//!
//! The [`Encoder`] is infallible (it grows a `Vec<u8>`); the
//! [`Decoder`] is fallible on every read — truncated or corrupt input
//! surfaces a structured `Error::Snapshot` instead of panicking, which
//! is what lets the snapshot store fall back a generation on a torn
//! frame. Length prefixes are validated against the bytes actually
//! remaining *before* any allocation, so a corrupt length word can
//! never ask the decoder for gigabytes.

use crate::error::{Error, Result};

/// FNV-1a, 64-bit: the per-frame checksum of the snapshot store. Not
/// cryptographic — it detects torn writes and bit rot, which is the
/// crash-consistency threat model — but dependency-free, stable across
/// platforms, and fast enough to run over every restored frame.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET_BASIS;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Append-only little-endian writer over a growable byte buffer.
#[derive(Debug, Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { buf: Vec::with_capacity(n) }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as `u64` so frames are portable across word sizes.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Floats travel as their IEEE-754 bit pattern — no text round trip,
    /// no rounding, NaN payloads preserved.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    /// Length-prefixed `f64` slice (the slab/vector workhorse).
    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_usize(v.len());
        self.buf.reserve(v.len() * 8);
        for &x in v {
            self.put_f64(x);
        }
    }

    /// Length-prefixed `usize` slice (graph segment schedules).
    pub fn put_usizes(&mut self, v: &[usize]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_usize(x);
        }
    }

    /// Length-prefixed UTF-8 string (benchmark names).
    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Fallible little-endian cursor over a byte slice. Every `take_*`
/// validates the remaining length first and returns `Error::Snapshot`
/// on truncation — the decoder never panics on corrupt input.
#[derive(Debug)]
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Snapshot(format!(
                "truncated {what} at byte {}: need {n} bytes, {} remain",
                self.pos,
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn take_u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn take_u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        let mut le = [0u8; 4];
        le.copy_from_slice(b);
        Ok(u32::from_le_bytes(le))
    }

    pub fn take_u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        let mut le = [0u8; 8];
        le.copy_from_slice(b);
        Ok(u64::from_le_bytes(le))
    }

    pub fn take_usize(&mut self, what: &str) -> Result<usize> {
        let v = self.take_u64(what)?;
        usize::try_from(v).map_err(|_| {
            Error::Snapshot(format!("{what}: value {v} does not fit this platform's usize"))
        })
    }

    pub fn take_f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.take_u64(what)?))
    }

    /// Strict bool: anything but 0/1 is corruption, not coercible truth.
    pub fn take_bool(&mut self, what: &str) -> Result<bool> {
        match self.take_u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(Error::Snapshot(format!("{what}: bad bool byte {v:#04x}"))),
        }
    }

    /// Length-prefixed `f64` vector; the prefix is checked against the
    /// remaining bytes *before* allocating.
    pub fn take_f64s(&mut self, what: &str) -> Result<Vec<f64>> {
        let n = self.take_usize(what)?;
        let bytes = n.checked_mul(8).ok_or_else(|| {
            Error::Snapshot(format!("{what}: length {n} overflows the byte count"))
        })?;
        if self.remaining() < bytes {
            return Err(Error::Snapshot(format!(
                "truncated {what}: length prefix {n} needs {bytes} bytes, {} remain",
                self.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.take_f64(what)?);
        }
        Ok(out)
    }

    /// Length-prefixed `usize` vector, with the same pre-allocation guard.
    pub fn take_usizes(&mut self, what: &str) -> Result<Vec<usize>> {
        let n = self.take_usize(what)?;
        let bytes = n.checked_mul(8).ok_or_else(|| {
            Error::Snapshot(format!("{what}: length {n} overflows the byte count"))
        })?;
        if self.remaining() < bytes {
            return Err(Error::Snapshot(format!(
                "truncated {what}: length prefix {n} needs {bytes} bytes, {} remain",
                self.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.take_usize(what)?);
        }
        Ok(out)
    }

    /// Length-prefixed UTF-8 string; invalid UTF-8 is corruption.
    pub fn take_str(&mut self, what: &str) -> Result<String> {
        let n = self.take_usize(what)?;
        if self.remaining() < n {
            return Err(Error::Snapshot(format!(
                "truncated {what}: string length {n}, {} bytes remain",
                self.remaining()
            )));
        }
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Snapshot(format!("{what}: invalid UTF-8")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_type_bit_exactly() {
        let mut e = Encoder::new();
        e.put_u8(7);
        e.put_u32(0xdead_beef);
        e.put_u64(u64::MAX - 1);
        e.put_usize(42);
        e.put_f64(-0.0);
        e.put_f64(f64::from_bits(0x7ff8_dead_beef_cafe)); // NaN with payload
        e.put_bool(true);
        e.put_f64s(&[1.5, f64::NEG_INFINITY, 2.5e-300]);
        e.put_usizes(&[0, 3, 9]);
        e.put_str("2d5pt");
        let bytes = e.finish();

        let mut d = Decoder::new(&bytes);
        assert_eq!(d.take_u8("a").unwrap(), 7);
        assert_eq!(d.take_u32("b").unwrap(), 0xdead_beef);
        assert_eq!(d.take_u64("c").unwrap(), u64::MAX - 1);
        assert_eq!(d.take_usize("d").unwrap(), 42);
        assert_eq!(d.take_f64("e").unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(d.take_f64("f").unwrap().to_bits(), 0x7ff8_dead_beef_cafe);
        assert!(d.take_bool("g").unwrap());
        let v = d.take_f64s("h").unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].to_bits(), 1.5f64.to_bits());
        assert_eq!(v[1].to_bits(), f64::NEG_INFINITY.to_bits());
        assert_eq!(v[2].to_bits(), 2.5e-300f64.to_bits());
        assert_eq!(d.take_usizes("i").unwrap(), vec![0, 3, 9]);
        assert_eq!(d.take_str("j").unwrap(), "2d5pt");
        assert!(d.is_empty());
    }

    #[test]
    fn truncation_errors_instead_of_panicking() {
        let mut e = Encoder::new();
        e.put_f64s(&[1.0, 2.0, 3.0]);
        let bytes = e.finish();
        // chop the last element off: the length prefix now overruns
        let torn = &bytes[..bytes.len() - 8];
        let err = Decoder::new(torn).take_f64s("slab").unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");
        // empty input errors on the very first read
        let err = Decoder::new(&[]).take_u64("hdr").unwrap_err();
        assert!(format!("{err}").contains("hdr"), "{err}");
    }

    #[test]
    fn corrupt_length_prefix_is_guarded_before_allocation() {
        // a length word claiming ~2^60 elements must be rejected by the
        // remaining-bytes check, never fed to Vec::with_capacity
        let mut e = Encoder::new();
        e.put_u64(1 << 60);
        let bytes = e.finish();
        let err = Decoder::new(&bytes).take_f64s("grid").unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");
        let err = Decoder::new(&bytes).take_usizes("segs").unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");
        let err = Decoder::new(&bytes).take_str("name").unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");
    }

    #[test]
    fn strict_bool_and_utf8_reject_corrupt_bytes() {
        let err = Decoder::new(&[2]).take_bool("loaded").unwrap_err();
        assert!(format!("{err}").contains("bad bool"), "{err}");
        let mut e = Encoder::new();
        e.put_usize(2);
        let mut bytes = e.finish();
        bytes.extend_from_slice(&[0xff, 0xfe]); // invalid UTF-8 pair
        let err = Decoder::new(&bytes).take_str("bench").unwrap_err();
        assert!(format!("{err}").contains("UTF-8"), "{err}");
    }

    #[test]
    fn fnv1a64_matches_the_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        // sensitivity: one flipped bit changes the sum
        let a = fnv1a64(&[0u8; 64]);
        let mut flipped = [0u8; 64];
        flipped[40] ^= 0x01;
        assert_ne!(a, fnv1a64(&flipped));
    }
}
