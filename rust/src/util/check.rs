//! Minimal property-based testing framework.
//!
//! `proptest`/`quickcheck` are not in the vendored dependency set, so this
//! module provides the subset we need: run a property against N generated
//! cases from a deterministic RNG and, on failure, report the seed and a
//! debug dump of the failing case so it can be replayed exactly.

use crate::util::rng::Rng;

/// Outcome of a property over one case.
pub enum Prop {
    Pass,
    Fail(String),
}

impl Prop {
    pub fn check(ok: bool, msg: impl Into<String>) -> Prop {
        if ok {
            Prop::Pass
        } else {
            Prop::Fail(msg.into())
        }
    }
}

impl From<bool> for Prop {
    fn from(b: bool) -> Self {
        if b {
            Prop::Pass
        } else {
            Prop::Fail("property returned false".into())
        }
    }
}

/// Run `prop` over `cases` values produced by `gen`, seeded deterministically.
///
/// Panics with the seed, case index and case debug dump on first failure —
/// rerunning with the same base seed replays the failure.
pub fn forall<T: std::fmt::Debug, P: Into<Prop>>(
    base_seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> P,
) {
    for i in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let case = gen(&mut rng);
        match prop(&case).into() {
            Prop::Pass => {}
            Prop::Fail(msg) => panic!(
                "property failed at case {i}/{cases} (base_seed={base_seed}): {msg}\ncase: {case:#?}"
            ),
        }
    }
}

/// Approximate float equality with relative + absolute tolerance.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Elementwise `close` over slices; returns first mismatch description.
pub fn allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Prop {
    if a.len() != b.len() {
        return Prop::Fail(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if !close(x, y, rtol, atol) {
            return Prop::Fail(format!("elem {i}: {x} vs {y} (diff {})", (x - y).abs()));
        }
    }
    Prop::Pass
}

/// f32 variant of `allclose`.
pub fn allclose_f32(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Prop {
    if a.len() != b.len() {
        return Prop::Fail(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if !close(x as f64, y as f64, rtol as f64, atol as f64) {
            return Prop::Fail(format!("elem {i}: {x} vs {y} (diff {})", (x - y).abs()));
        }
    }
    Prop::Pass
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 50, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 50, |r| r.below(100), |&x| x < 50);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-8, 0.0));
        assert!(!close(1.0, 1.1, 1e-8, 1e-8));
        assert!(close(0.0, 1e-12, 0.0, 1e-9));
    }

    #[test]
    fn allclose_detects_mismatch() {
        match allclose(&[1.0, 2.0], &[1.0, 2.5], 1e-6, 1e-6) {
            Prop::Fail(msg) => assert!(msg.contains("elem 1")),
            Prop::Pass => panic!("expected failure"),
        }
    }
}
