//! Small statistics helpers used by benches, the harness and the simulator.

/// Smallest wall-clock interval we trust from `Instant` (1 ns). Rates are
/// computed against `max(wall, MIN_WALL_SECONDS)` so a 0-duration run
/// (possible on very fast runs with coarse clocks) yields a finite FOM.
pub const MIN_WALL_SECONDS: f64 = 1e-9;

/// `units / wall_seconds`, clamped to a measurable wall time so the result
/// is always finite (no `inf`/`NaN` from 0-duration runs).
pub fn finite_rate(units: f64, wall_seconds: f64) -> f64 {
    units / wall_seconds.max(MIN_WALL_SECONDS)
}

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; the paper reports all aggregate speedups this way.
/// Panics in debug if any value is non-positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Percentile in [0, 100] with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Min of a slice (NaN-free inputs assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Measure wall time of `f` in seconds.
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` `n` times, returning per-run seconds. A single warmup run is
/// executed first and discarded (PJRT compiles lazily on first execute).
pub fn time_n(n: usize, mut f: impl FnMut()) -> Vec<f64> {
    f(); // warmup
    (0..n)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finite_rate_never_inf_or_nan() {
        assert!(finite_rate(1e9, 0.0).is_finite());
        assert!(finite_rate(0.0, 0.0).is_finite());
        assert_eq!(finite_rate(0.0, 0.0), 0.0);
        // ordinary case unaffected by the clamp
        assert_eq!(finite_rate(10.0, 2.0), 5.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        // geomean of identical values is the value
        assert!((geomean(&[2.29, 2.29, 2.29]) - 2.29).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_paper_style_aggregation() {
        // speedups 2x and 0.5x must aggregate to 1.0 (not 1.25 as mean would)
        assert!((geomean(&[2.0, 0.5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn median_and_percentile() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn minmax() {
        let xs = [2.0, -1.0, 7.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.0);
    }
}
