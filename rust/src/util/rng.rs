//! Deterministic PRNGs for workload generation and property tests.
//!
//! No external `rand` crate is available in the vendored dependency set,
//! so we implement SplitMix64 (seeding) and xoshiro256** (bulk generation)
//! from the published reference algorithms. Both are tiny, fast and have
//! well-understood statistical quality for simulation workloads.

/// SplitMix64: used to expand a single u64 seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the library's workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0). Lemire-style rejection-free
    /// multiply-shift; bias is negligible for n << 2^64.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform f32 in [-1, 1).
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = (self.f64() * 2.0 - 1.0) as f32;
        }
    }

    /// Fill a slice with uniform f64 in [-1, 1).
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.f64() * 2.0 - 1.0;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
