//! Shared utilities: deterministic PRNGs, statistics, formatting, typed
//! CLI argument parsing, and a minimal property-testing framework
//! (external test/bench crates are not available in the vendored
//! dependency set).

pub mod args;
pub mod check;
pub mod fmt;
pub mod rng;
pub mod stats;
