//! Shared utilities: deterministic PRNGs, statistics, formatting, typed
//! CLI argument parsing, and a minimal property-testing framework
//! (external test/bench crates are not available in the vendored
//! dependency set).

pub mod args;
pub mod check;
pub mod codec;
pub mod counters;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod stats;

/// Resolve a `0 = auto` worker-count knob to a concrete count, exactly
/// once per solve: `0` maps to `available_parallelism` (fallback 8 where
/// the sysconf is unavailable), anything else passes through. Every
/// threaded substrate (`spmv::merge::spmv_parallel`, `cg::pool`,
/// `session::cpu::CpuCg`, `cg::solver`) resolves through this one helper
/// so their worker counts can never silently diverge.
pub fn resolve_workers(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8)
    } else {
        requested
    }
}
