//! Process-wide instrumentation counters.
//!
//! The PERKS claim hinges on *how often* the host relaunches workers and
//! *how often* the device grid synchronizes, so the threading substrates
//! (`spmv::merge::spmv_parallel`, `stencil::parallel::host_loop`,
//! `stencil::pool`, `cg::pool`) report every OS thread they spawn here,
//! and `coordinator::barrier::GridBarrier` reports every completed sync
//! generation — plus, separately, every slot-ordered *reduction*
//! generation ([`barrier_reductions`]), which is how the CG solvers'
//! barriers-per-iteration invariant is asserted (classic = 2/iter,
//! pipelined = 1/iter). Benches snapshot [`thread_spawns`] / [`barrier_syncs`]
//! around a measured region to show the spawn-per-iteration baseline
//! against the spawn-once pools, and the barriers-per-step reduction of
//! epoch-batched temporal blocking (2 per epoch instead of 2 per step).
//!
//! The submission plane (`runtime::plane`) adds its own family:
//! [`plane_batches`] / [`sched_lock_acquisitions`] assert that batched
//! command graphs pay one enqueue-lock acquisition per *batch* rather
//! than per epoch, and [`plane_sheds`] / [`plane_timeouts`] count
//! admission-control backpressure.
//!
//! The resilience layer (`runtime::resilience`) adds the recovery
//! family: [`faults_injected`] counts `FaultPlan` coordinates claimed,
//! [`farm_recoveries`] / [`replayed_epochs`] count checkpoint-restore
//! replays and the epochs they re-execute, and [`checkpoint_bytes`]
//! counts resident-state snapshot traffic. Clean benches assert
//! recoveries stay 0; `bench_check` gates it.
//!
//! The durable snapshot store (`runtime::resilience::snapshot`) adds
//! the durability family: [`durable_frames`] / [`durable_bytes`] count
//! crash-consistent frames (and their on-disk bytes) committed to a
//! snapshot directory, and [`restores`] counts checkpoints successfully
//! read back and verified from disk. Cadence-0 runs assert frames stay
//! 0 and clean runs assert restores stay 0; `bench_check` gates both.
//!
//! The counters are global and monotonic; concurrent test threads may
//! interleave increments, so tests that need an exact attribution use the
//! per-pool counters (`cg::pool::CgPool::spawn_count`,
//! `stencil::pool::StencilPool::spawn_count`,
//! `stencil::pool::StencilPool::barrier_syncs`) instead and benches
//! (single-threaded mains) read these.
//!
//! ## Memory ordering
//!
//! Two regimes, chosen per counter family (each `note_*` documents its
//! own pairing the way a loom model would name its interleavings):
//!
//! - **Relaxed** for [`thread_spawns`] and [`barrier_syncs`]: every
//!   reader observes them only after a join or a completion handshake
//!   (scope exit, pool `finished` countdown), which already publishes
//!   the increments with a stronger edge. Relaxed still guarantees a
//!   per-counter total modification order, so monotonicity asserts
//!   (`after >= before + n`) can never observe a decrease.
//! - **Release increments / Acquire loads** for the farm, plane, and
//!   resilience families: integration tests assert their deltas while
//!   *other* tests' farms are still running workers that increment the
//!   same statics. The Release/Acquire pairing makes each counted
//!   event's side effects (the shed error, the restored state, the
//!   checkpoint copy) visible to any reader that observes its count, so
//!   an assert that sees `plane_sheds() >= base + 1` is also entitled
//!   to see the `Error::Shed` that paid for it.

use std::sync::atomic::{AtomicU64, Ordering};

static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);
static BARRIER_SYNCS: AtomicU64 = AtomicU64::new(0);
static BARRIER_REDUCTIONS: AtomicU64 = AtomicU64::new(0);
static FARM_ADMISSIONS: AtomicU64 = AtomicU64::new(0);
static FARM_COMMANDS: AtomicU64 = AtomicU64::new(0);
static FARM_TASKS: AtomicU64 = AtomicU64::new(0);
static PLANE_BATCHES: AtomicU64 = AtomicU64::new(0);
static SCHED_LOCK_ACQUISITIONS: AtomicU64 = AtomicU64::new(0);
static PLANE_SHEDS: AtomicU64 = AtomicU64::new(0);
static PLANE_TIMEOUTS: AtomicU64 = AtomicU64::new(0);
static FAULTS_INJECTED: AtomicU64 = AtomicU64::new(0);
static FARM_RECOVERIES: AtomicU64 = AtomicU64::new(0);
static REPLAYED_EPOCHS: AtomicU64 = AtomicU64::new(0);
static CHECKPOINT_BYTES: AtomicU64 = AtomicU64::new(0);
static DURABLE_FRAMES: AtomicU64 = AtomicU64::new(0);
static DURABLE_BYTES: AtomicU64 = AtomicU64::new(0);
static RESTORES: AtomicU64 = AtomicU64::new(0);

/// Record `n` OS threads spawned by a solver substrate.
pub fn note_thread_spawns(n: u64) {
    THREAD_SPAWNS.fetch_add(n, Ordering::Relaxed);
}

/// Total OS threads spawned by solver substrates since process start.
pub fn thread_spawns() -> u64 {
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

/// Record `n` completed grid-barrier sync generations (the barrier's
/// leader reports once per generation, not once per arriving thread).
pub fn note_barrier_syncs(n: u64) {
    BARRIER_SYNCS.fetch_add(n, Ordering::Relaxed);
}

/// Total grid-barrier sync generations since process start.
pub fn barrier_syncs() -> u64 {
    BARRIER_SYNCS.load(Ordering::Relaxed)
}

/// Record `n` completed slot-ordered **reduction** generations
/// (`GridBarrier::sync_reduce`, reported once by the leader like
/// [`note_barrier_syncs`]). This is the counter behind the
/// barriers-per-iteration invariant of the CG solvers: a classic pooled
/// CG iteration pays exactly two reduction generations (p·Ap, then r·r),
/// a pipelined pooled iteration pays exactly one (γ/δ/r·r folded out of
/// a single generation). Relaxed for the same reason as
/// [`note_barrier_syncs`]: every reader is behind the pool's completion
/// handshake.
pub fn note_barrier_reductions(n: u64) {
    BARRIER_REDUCTIONS.fetch_add(n, Ordering::Relaxed);
}

/// Total slot-ordered reduction generations since process start.
pub fn barrier_reductions() -> u64 {
    BARRIER_REDUCTIONS.load(Ordering::Relaxed)
}

/// Record `n` sessions admitted to a [`crate::runtime::farm::SolverFarm`].
/// The multi-tenant acceptance bar is that this moves while
/// [`thread_spawns`] does **not**: admissions reuse the farm's resident
/// workers instead of building pools.
pub fn note_farm_admissions(n: u64) {
    // pairing: writer: client thread at admit; reader: any test thread auditing admissions (Acquire load below).
    FARM_ADMISSIONS.fetch_add(n, Ordering::Release);
}

/// Total farm session admissions since process start.
pub fn farm_admissions() -> u64 {
    FARM_ADMISSIONS.load(Ordering::Acquire)
}

/// Record `n` commands (advance/advance_until/run) enqueued to farms.
pub fn note_farm_commands(n: u64) {
    // pairing: writer: client thread at submit; reader: any test thread auditing commands (Acquire load below).
    FARM_COMMANDS.fetch_add(n, Ordering::Release);
}

/// Total farm commands since process start.
pub fn farm_commands() -> u64 {
    FARM_COMMANDS.load(Ordering::Acquire)
}

/// Record `n` completed farm shard tasks (the farm's unit of scheduled
/// work — band or block shards of one phase).
pub fn note_farm_tasks(n: u64) {
    // pairing: writer: farm worker at task completion; reader: racing test assert (Acquire load below).
    FARM_TASKS.fetch_add(n, Ordering::Release);
}

/// Total farm shard tasks since process start.
pub fn farm_tasks() -> u64 {
    FARM_TASKS.load(Ordering::Acquire)
}

/// Record `n` batches enqueued to the submission plane (one per
/// `submit`/`submit_graph`, however many segments the batch chains).
pub fn note_plane_batches(n: u64) {
    // pairing: writer: submitting client under the scheduler lock; reader: racing test assert (Acquire load below).
    PLANE_BATCHES.fetch_add(n, Ordering::Release);
}

/// Total submission-plane batches since process start.
pub fn plane_batches() -> u64 {
    PLANE_BATCHES.load(Ordering::Acquire)
}

/// Record `n` scheduler-lock acquisitions taken to *enqueue* work. The
/// batched-graph acceptance bar is that this equals [`plane_batches`]:
/// segment boundaries are dequeued inside the farm's completion
/// transition under the already-held lock, never by a client re-acquire
/// per epoch.
pub fn note_sched_lock_acquisitions(n: u64) {
    // pairing: writer: submitting client at enqueue; reader: racing test assert (Acquire load below).
    SCHED_LOCK_ACQUISITIONS.fetch_add(n, Ordering::Release);
}

/// Total enqueue-side scheduler-lock acquisitions since process start.
pub fn sched_lock_acquisitions() -> u64 {
    SCHED_LOCK_ACQUISITIONS.load(Ordering::Acquire)
}

/// Record `n` submissions shed by admission control (`Shed` policy or a
/// batch larger than the configured caps).
pub fn note_plane_sheds(n: u64) {
    // pairing: writer: rejected submitter; reader: a test pairing the count with the Shed error (Acquire load below).
    PLANE_SHEDS.fetch_add(n, Ordering::Release);
}

/// Total shed submissions since process start.
pub fn plane_sheds() -> u64 {
    PLANE_SHEDS.load(Ordering::Acquire)
}

/// Record `n` submissions that timed out waiting for a plane slot
/// (`Timeout` admission policy).
pub fn note_plane_timeouts(n: u64) {
    // pairing: writer: expired submitter; reader: a test pairing the count with the Timeout error (Acquire load below).
    PLANE_TIMEOUTS.fetch_add(n, Ordering::Release);
}

/// Total timed-out submissions since process start.
pub fn plane_timeouts() -> u64 {
    PLANE_TIMEOUTS.load(Ordering::Acquire)
}

/// Record `n` faults injected by an installed
/// `runtime::resilience::FaultPlan` (panic / NaN / stall coordinates
/// claimed by the farm scheduler). Clean benches assert this stays 0.
pub fn note_faults_injected(n: u64) {
    // pairing: writer: farm scheduler at claim; reader: racing clean-bench/test assert (Acquire load below).
    FAULTS_INJECTED.fetch_add(n, Ordering::Release);
}

/// Total injected faults since process start.
pub fn faults_injected() -> u64 {
    FAULTS_INJECTED.load(Ordering::Acquire)
}

/// Record `n` supervised recoveries: a retryable failure (panicked or
/// NaN-tripped command) restored from its last checkpoint and replayed
/// under a `runtime::resilience::RetryPolicy`.
pub fn note_farm_recoveries(n: u64) {
    // pairing: writer: farm transition during restore; reader: racing test assert (Acquire load below).
    FARM_RECOVERIES.fetch_add(n, Ordering::Release);
}

/// Total supervised recoveries since process start. The clean-bench
/// invariant gated by `bench_check` is that this stays 0 without
/// injection.
pub fn farm_recoveries() -> u64 {
    FARM_RECOVERIES.load(Ordering::Acquire)
}

/// Record `n` epochs re-executed by recovery replays (the distance from
/// the restored checkpoint to the failure point — the work the
/// checkpoint cadence bounds).
pub fn note_replayed_epochs(n: u64) {
    // pairing: writer: farm transition during restore; reader: racing test assert (Acquire load below).
    REPLAYED_EPOCHS.fetch_add(n, Ordering::Release);
}

/// Total replayed epochs since process start.
pub fn replayed_epochs() -> u64 {
    REPLAYED_EPOCHS.load(Ordering::Acquire)
}

/// Record `n` bytes copied into resident-state checkpoints (cadence
/// snapshots and command-entry snapshots alike).
pub fn note_checkpoint_bytes(n: u64) {
    // pairing: writer: checkpointing worker/transition; reader: racing test assert (Acquire load below).
    CHECKPOINT_BYTES.fetch_add(n, Ordering::Release);
}

/// Total checkpointed bytes since process start.
pub fn checkpoint_bytes() -> u64 {
    CHECKPOINT_BYTES.load(Ordering::Acquire)
}

/// Record `n` durable snapshot frames committed (tmp-write + fsync +
/// atomic rename + manifest commit completed). The cadence-0 invariant
/// gated by `bench_check` is that this stays 0 with durability off.
pub fn note_durable_frames(n: u64) {
    // pairing: writer: off-lock durable write-out after commit; reader: racing test assert (Acquire load below).
    DURABLE_FRAMES.fetch_add(n, Ordering::Release);
}

/// Total durable snapshot frames committed since process start.
pub fn durable_frames() -> u64 {
    DURABLE_FRAMES.load(Ordering::Acquire)
}

/// Record `n` bytes written to durable snapshot frames (frame header +
/// encoded payload; manifest bytes excluded).
pub fn note_durable_bytes(n: u64) {
    // pairing: writer: off-lock durable write-out after commit; reader: racing test assert (Acquire load below).
    DURABLE_BYTES.fetch_add(n, Ordering::Release);
}

/// Total durable snapshot bytes written since process start.
pub fn durable_bytes() -> u64 {
    DURABLE_BYTES.load(Ordering::Acquire)
}

/// Record `n` checkpoints successfully restored from a snapshot
/// directory (checksum verified; fallback generations that failed
/// verification are *not* counted). The clean-run invariant gated by
/// `bench_check` is that this stays 0 without a restart.
pub fn note_restores(n: u64) {
    // pairing: writer: restoring client at verify success; reader: racing test assert (Acquire load below).
    RESTORES.fetch_add(n, Ordering::Release);
}

/// Total verified snapshot restores since process start.
pub fn restores() -> u64 {
    RESTORES.load(Ordering::Acquire)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_counter_is_monotonic() {
        let before = thread_spawns();
        note_thread_spawns(3);
        assert!(thread_spawns() >= before + 3);
    }

    #[test]
    fn barrier_counter_is_monotonic() {
        let before = barrier_syncs();
        note_barrier_syncs(2);
        assert!(barrier_syncs() >= before + 2);
    }

    #[test]
    fn reduction_counter_is_monotonic() {
        let before = barrier_reductions();
        note_barrier_reductions(2);
        assert!(barrier_reductions() >= before + 2);
    }

    #[test]
    fn plane_counters_are_monotonic() {
        let (b, l, s, t) = (
            plane_batches(),
            sched_lock_acquisitions(),
            plane_sheds(),
            plane_timeouts(),
        );
        note_plane_batches(2);
        note_sched_lock_acquisitions(2);
        note_plane_sheds(1);
        note_plane_timeouts(1);
        assert!(plane_batches() >= b + 2);
        assert!(sched_lock_acquisitions() >= l + 2);
        assert!(plane_sheds() >= s + 1);
        assert!(plane_timeouts() >= t + 1);
    }

    #[test]
    fn resilience_counters_are_monotonic() {
        let (f, r, e, b) =
            (faults_injected(), farm_recoveries(), replayed_epochs(), checkpoint_bytes());
        note_faults_injected(1);
        note_farm_recoveries(1);
        note_replayed_epochs(5);
        note_checkpoint_bytes(4096);
        assert!(faults_injected() >= f + 1);
        assert!(farm_recoveries() >= r + 1);
        assert!(replayed_epochs() >= e + 5);
        assert!(checkpoint_bytes() >= b + 4096);
    }

    #[test]
    fn durable_counters_are_monotonic() {
        let (f, b, r) = (durable_frames(), durable_bytes(), restores());
        note_durable_frames(1);
        note_durable_bytes(8192);
        note_restores(1);
        assert!(durable_frames() >= f + 1);
        assert!(durable_bytes() >= b + 8192);
        assert!(restores() >= r + 1);
    }

    #[test]
    fn farm_counters_are_monotonic() {
        let (a, c, t) = (farm_admissions(), farm_commands(), farm_tasks());
        note_farm_admissions(1);
        note_farm_commands(2);
        note_farm_tasks(3);
        assert!(farm_admissions() >= a + 1);
        assert!(farm_commands() >= c + 2);
        assert!(farm_tasks() >= t + 3);
    }
}
