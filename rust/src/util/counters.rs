//! Process-wide instrumentation counters.
//!
//! The PERKS claim hinges on *how often* the host relaunches workers, so
//! the threading substrates (`spmv::merge::spmv_parallel`,
//! `stencil::parallel::host_loop`, `stencil::pool`, `cg::pool`) report
//! every OS thread they spawn here. Benches snapshot [`thread_spawns`]
//! around a measured region to show the spawn-per-iteration baseline
//! against the spawn-once pools.
//!
//! The counter is global and monotonic; concurrent test threads may
//! interleave increments, so tests that need an exact attribution use the
//! per-pool counters (`cg::pool::CgPool::spawn_count`,
//! `stencil::pool::StencilPool::spawn_count`) instead and benches
//! (single-threaded mains) read this one.

use std::sync::atomic::{AtomicU64, Ordering};

static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

/// Record `n` OS threads spawned by a solver substrate.
pub fn note_thread_spawns(n: u64) {
    THREAD_SPAWNS.fetch_add(n, Ordering::Relaxed);
}

/// Total OS threads spawned by solver substrates since process start.
pub fn thread_spawns() -> u64 {
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_counter_is_monotonic() {
        let before = thread_spawns();
        note_thread_spawns(3);
        assert!(thread_spawns() >= before + 3);
    }
}
