//! Typed CLI argument parsing shared by the `perks` binary's subcommands.
//!
//! Each subcommand declares a *closed* set of `--key value` flags and a
//! maximum number of positional arguments; anything outside that set is an
//! `Error::Invalid` rather than a silent drop (the failure mode of the old
//! hand-rolled map: `perks run-stencil --step 128` would quietly run 64
//! steps). Typed getters surface parse failures the same way.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed arguments of one subcommand invocation.
#[derive(Clone, Debug)]
pub struct ParsedArgs {
    cmd: String,
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl ParsedArgs {
    /// Parse the tokens following the subcommand name against a closed set
    /// of flags. Every flag takes exactly one value; unknown flags, missing
    /// values, duplicates, and excess positional arguments are errors.
    pub fn parse<I>(cmd: &str, tokens: I, allowed: &[&str], max_positional: usize) -> Result<Self>
    where
        I: IntoIterator<Item = String>,
    {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if !allowed.contains(&key) {
                    return Err(Error::invalid(format!(
                        "{cmd}: unknown flag --{key}{}",
                        if allowed.is_empty() {
                            " (this command takes no flags)".to_string()
                        } else {
                            format!(
                                " (valid: {})",
                                allowed
                                    .iter()
                                    .map(|a| format!("--{a}"))
                                    .collect::<Vec<_>>()
                                    .join(", ")
                            )
                        }
                    )));
                }
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => {
                        return Err(Error::invalid(format!(
                            "{cmd}: flag --{key} requires a value"
                        )))
                    }
                };
                if flags.insert(key.to_string(), val).is_some() {
                    return Err(Error::invalid(format!("{cmd}: duplicate flag --{key}")));
                }
            } else {
                if positional.len() == max_positional {
                    return Err(Error::invalid(format!(
                        "{cmd}: unexpected argument {tok:?}"
                    )));
                }
                positional.push(tok);
            }
        }
        Ok(Self { cmd: cmd.to_string(), flags, positional })
    }

    /// String flag with a default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Integer flag with a default; a present-but-unparsable value is an
    /// error (the old parser silently fell back to the default).
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::invalid(format!(
                    "{}: flag --{key} expects an integer, got {v:?}",
                    self.cmd
                ))
            }),
        }
    }

    /// i-th positional argument, if present.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = ParsedArgs::parse(
            "simulate",
            toks(&["fig5", "--device", "V100"]),
            &["device", "dtype"],
            1,
        )
        .unwrap();
        assert_eq!(a.positional(0), Some("fig5"));
        assert_eq!(a.get("device", "A100"), "V100");
        assert_eq!(a.get("dtype", "f64"), "f64");
    }

    #[test]
    fn unknown_flag_is_an_error() {
        let err = ParsedArgs::parse("run-stencil", toks(&["--step", "64"]), &["steps"], 0);
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("unknown flag --step"), "{msg}");
        assert!(msg.contains("--steps"), "{msg}");
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(ParsedArgs::parse("x", toks(&["--steps"]), &["steps"], 0).is_err());
        assert!(
            ParsedArgs::parse("x", toks(&["--steps", "--bench", "2d5pt"]), &["steps", "bench"], 0)
                .is_err()
        );
    }

    #[test]
    fn duplicate_flag_is_an_error() {
        assert!(ParsedArgs::parse(
            "x",
            toks(&["--n", "1", "--n", "2"]),
            &["n"],
            0
        )
        .is_err());
    }

    #[test]
    fn excess_positional_is_an_error() {
        assert!(ParsedArgs::parse("info", toks(&["stray"]), &[], 0).is_err());
    }

    #[test]
    fn typed_getter_rejects_garbage() {
        let a = ParsedArgs::parse("x", toks(&["--n", "12x"]), &["n"], 0).unwrap();
        assert!(a.get_usize("n", 7).is_err());
        let b = ParsedArgs::parse("x", toks(&["--n", "12"]), &["n"], 0).unwrap();
        assert_eq!(b.get_usize("n", 7).unwrap(), 12);
        assert_eq!(b.get_usize("m", 7).unwrap(), 7);
    }
}
