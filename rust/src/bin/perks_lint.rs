//! `perks_lint` — the project's static analysis gate for persistent-
//! runtime concurrency invariants (see `perks::lint` and
//! `docs/INVARIANTS.md`).
//!
//! ```text
//! cargo run --bin perks_lint                  # lint rust/src (run from rust/)
//! cargo run --bin perks_lint -- --root src    # explicit tree root
//! cargo run --bin perks_lint -- --list-rules  # print the rule catalogue
//! cargo run --bin perks_lint -- file.rs …     # lint specific files only
//! ```
//!
//! Exit status: 0 clean, 1 violations found, 2 usage or I/O error. CI
//! runs this as a blocking step in the `lint` job.

use std::path::PathBuf;
use std::process::ExitCode;

use perks::lint::{self, FileCtx, Violation};

struct Args {
    root: PathBuf,
    files: Vec<PathBuf>,
    list_rules: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args =
        Args { root: PathBuf::from("src"), files: Vec::new(), list_rules: false };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => {
                args.root =
                    PathBuf::from(it.next().ok_or("--root needs a directory argument")?);
            }
            "--list-rules" => args.list_rules = true,
            "--help" | "-h" => {
                return Err("usage: perks_lint [--root DIR] [--list-rules] [FILE…]".into())
            }
            f if !f.starts_with('-') => args.files.push(PathBuf::from(f)),
            other => return Err(format!("unknown flag `{other}`")),
        }
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    if args.list_rules {
        println!("perks-lint rules (suppress with `// lint: allow(rule) -- justification`):");
        for (name, desc) in lint::RULES {
            println!("  {name:<18} {desc}");
        }
        return ExitCode::SUCCESS;
    }
    let result: std::io::Result<Vec<Violation>> = if args.files.is_empty() {
        lint::lint_root(&args.root)
    } else {
        // explicit file mode: per-file rules only (counter coverage is a
        // whole-tree property)
        args.files
            .iter()
            .map(|f| FileCtx::load(f).map(|ctx| lint::lint_file(&ctx)))
            .collect::<std::io::Result<Vec<_>>>()
            .map(|vs| vs.into_iter().flatten().collect())
    };
    match result {
        Ok(violations) if violations.is_empty() => {
            println!("perks-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                println!("{v}");
            }
            println!("perks-lint: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("perks-lint: {e}");
            ExitCode::from(2)
        }
    }
}
