//! `bench_check` — the CI perf-regression gate over `BENCH_*.json`
//! artifacts (the `tools/bench_check` binary of the perf-smoke job).
//!
//! Reads the `BENCH_stencil.json` / `BENCH_temporal.json` /
//! `BENCH_farm.json` / `BENCH_plane.json` / `BENCH_resilience.json` /
//! `BENCH_cg_pipeline.json` files the quick-mode benches emit and fails
//! (exit 1) on:
//!
//! * **counter-invariant breaks** — machine-independent, always checked:
//!   any pooled/persistent arm with `advance_spawns > 0` (a resident
//!   advance must never spawn), any pooled arm whose `barrier_syncs` is
//!   not exactly `2 * ceil(steps / bt) + 1` (two per exchange epoch plus
//!   the one-time initial-load sync), any farm row with
//!   `admission_spawns > 0`, any farm row at >= 16 tenants whose
//!   farm-vs-pool-per-session speedup falls below the acceptance floor
//!   (default 1.5, `--min-farm-speedup`), and any plane row whose
//!   batched path leaks (`sched_lock_acquisitions != plane_batches`) or
//!   that sheds / times out / spawns under the quick load (all must be
//!   0 — the unbounded quick config admits everything), any resilience
//!   row that recovers without an injected fault (or fails to recover
//!   with one), a cadence-0 arm that copies checkpoint bytes, a
//!   default-cadence clean arm costing more than 5% over its cadence-0
//!   reference (skipped below a small noise-floor wall), any cg_pipeline
//!   arm whose barrier-reduction count is not exactly `iters` (pipelined)
//!   or `2 * iters` (classic), and a pipelined arm losing to its classic
//!   twin by more than the jitter allowance on the small-system sweep;
//! * **wall regressions** — current wall > baseline wall * (1 + tol)
//!   (default tolerance 0.25, `--tolerance`), compared against the
//!   checked-in `bench/baselines/*.json` entry with the *same workload
//!   configuration*; entries whose configuration differs (e.g. a full
//!   run checked against quick baselines) are skipped with a note.
//!   `--no-wall` skips wall gates entirely (for cross-machine runs);
//!   `--update` rewrites the baselines from the current artifacts after
//!   the invariants pass — run it once on a new CI runner class and
//!   commit the result.
//!
//! Usage:
//!   bench_check [--dir .] [--baseline-dir ../bench/baselines]
//!               [--tolerance 0.25] [--min-farm-speedup 1.5]
//!               [--no-wall] [--update] [--list-invariants]
//!
//! `--list-invariants` prints every machine-independent invariant this
//! gate enforces (one per line, `name: statement`) and exits 0 — the
//! human-auditable twin of `perks_lint --list-rules`; the catalogue in
//! `docs/INVARIANTS.md` is generated from the same set.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use perks::util::json::Json;

const FILES: [&str; 6] = [
    "BENCH_stencil.json",
    "BENCH_temporal.json",
    "BENCH_farm.json",
    "BENCH_plane.json",
    "BENCH_resilience.json",
    "BENCH_cg_pipeline.json",
];

/// Checkpoint-overhead acceptance bar: the default-cadence clean arm may
/// cost at most this much over the cadence-0 arm of the same case.
const MAX_CHECKPOINT_OVERHEAD: f64 = 0.05;

/// Durable-write acceptance bar: the default-cadence **durable** arm
/// (crash-consistent frame persistence on — `"durable":1` rows) may cost
/// at most this much over the durable cadence-0 arm of the same case.
const MAX_DURABLE_OVERHEAD: f64 = 0.10;

/// Walls shorter than this are too noisy for the within-artifact
/// overhead ratio; the gate notes and skips them (the checked-in
/// baseline wall gate still applies).
const OVERHEAD_GATE_MIN_WALL: f64 = 0.005;

/// Pipelined-vs-classic acceptance bar: on the small-system sweep (where
/// the barrier dominates the SpMV) the pipelined arm may lose to its
/// classic twin by at most this much wall — any more and the collapsed
/// barrier has stopped paying for its auxiliary recurrences.
const MAX_PIPELINE_JITTER: f64 = 0.10;

/// The machine-independent invariants this gate enforces, as
/// `(name, statement)` pairs for `--list-invariants`. Keep in sync with
/// the checks in `check_modes`/`check_file` and `docs/INVARIANTS.md`.
const INVARIANTS: [(&str, &str); 14] = [
    (
        "zero-spawn-advance",
        "persistent/pooled arms and farm admissions perform 0 thread spawns (advance_spawns == 0, admission_spawns == 0)",
    ),
    (
        "exact-barrier-count",
        "a pooled arm's first advance syncs exactly 2*ceil(steps/bt)+1 barrier generations",
    ),
    (
        "host-loop-respawns",
        "the host-loop baseline reports nonzero advance spawns (otherwise the measurement is broken)",
    ),
    (
        "farm-speedup-floor",
        "farm rows at >= 16 tenants keep farm-vs-pool-per-session speedup above the --min-farm-speedup floor",
    ),
    (
        "one-lock-per-batch",
        "plane rows take exactly one enqueue-side scheduler-lock acquisition per batch (sched_lock_acquisitions == plane_batches)",
    ),
    (
        "quiet-quick-plane",
        "plane rows under the unbounded quick load never shed, time out, or spawn",
    ),
    (
        "no-spurious-recovery",
        "resilience rows recover if and only if a fault was injected",
    ),
    (
        "cadence-zero-is-free",
        "cadence-0 clean rows copy 0 checkpoint bytes",
    ),
    (
        "checkpoint-overhead-bound",
        "the default-cadence clean arm costs at most 5% wall over its cadence-0 reference (above the noise floor)",
    ),
    (
        "durable-cadence-zero-writes-nothing",
        "cadence-0 durable rows commit 0 durable frames and 0 durable bytes",
    ),
    (
        "durable-clean-never-restores",
        "clean durable rows perform 0 snapshot restores",
    ),
    (
        "durable-overhead-bound",
        "the default-cadence durable arm costs at most 10% wall over its durable cadence-0 reference (above the noise floor)",
    ),
    (
        "pipelined-single-reduction",
        "a pipelined CG arm pays exactly one slot-ordered barrier reduction per iteration (barrier_reductions == iters); the classic arm pays exactly two",
    ),
    (
        "pipelined-wall-win",
        "on the small-system sweep the pipelined arm's wall stays within the jitter allowance of its classic twin (above the noise floor)",
    ),
];

struct Config {
    dir: PathBuf,
    baseline_dir: PathBuf,
    tolerance: f64,
    min_farm_speedup: f64,
    no_wall: bool,
    update: bool,
}

fn parse_args() -> Result<Config, String> {
    let mut cfg = Config {
        dir: PathBuf::from("."),
        baseline_dir: PathBuf::from("../bench/baselines"),
        tolerance: 0.25,
        min_farm_speedup: 1.5,
        no_wall: false,
        update: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(tok) = it.next() {
        let mut take = |name: &str| {
            it.next().ok_or_else(|| format!("{name} requires a value"))
        };
        match tok.as_str() {
            "--dir" => cfg.dir = PathBuf::from(take("--dir")?),
            "--baseline-dir" => cfg.baseline_dir = PathBuf::from(take("--baseline-dir")?),
            "--tolerance" => {
                cfg.tolerance = take("--tolerance")?
                    .parse()
                    .map_err(|_| "--tolerance must be a number".to_string())?
            }
            "--min-farm-speedup" => {
                cfg.min_farm_speedup = take("--min-farm-speedup")?
                    .parse()
                    .map_err(|_| "--min-farm-speedup must be a number".to_string())?
            }
            "--no-wall" => cfg.no_wall = true,
            "--update" => cfg.update = true,
            "--list-invariants" => {
                for (name, statement) in INVARIANTS {
                    println!("{name}: {statement}");
                }
                std::process::exit(0);
            }
            other => return Err(format!("unknown flag {other:?} (see --help in module docs)")),
        }
    }
    Ok(cfg)
}

fn load(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn num(v: &Json, key: &str) -> Option<f64> {
    v.get(key).and_then(Json::as_f64)
}

fn int(v: &Json, key: &str) -> Option<u64> {
    v.get(key).and_then(Json::as_u64)
}

fn s<'a>(v: &'a Json, key: &str) -> &'a str {
    v.get(key).and_then(Json::as_str).unwrap_or("")
}

/// Exact barrier accounting of a pooled arm's *first* advance after
/// prepare: `2 * ceil(steps / bt)` epoch pairs plus the initial-load sync.
fn expected_barriers(steps: u64, bt: u64) -> u64 {
    2 * steps.div_ceil(bt.max(1)) + 1
}

/// Invariants of one `modes` array (shared by the stencil and temporal
/// schemas): pooled arms never spawn and sync exactly per the epoch
/// formula; the host-loop baseline must actually respawn.
fn check_modes(label: &str, steps: u64, modes: &[Json], fails: &mut Vec<String>) {
    for m in modes {
        let mode = s(m, "mode");
        let bt = int(m, "bt").unwrap_or(1);
        let spawns = int(m, "advance_spawns");
        let syncs = int(m, "barrier_syncs");
        match mode {
            "persistent" => {
                if spawns != Some(0) {
                    fails.push(format!(
                        "{label}: pooled bt={bt} arm spawned {spawns:?} threads per advance (must be 0)"
                    ));
                }
                let want = expected_barriers(steps, bt);
                if syncs != Some(want) {
                    fails.push(format!(
                        "{label}: pooled bt={bt} arm performed {syncs:?} barrier syncs, expected {want} (= 2*ceil({steps}/{bt})+1)"
                    ));
                }
            }
            "host-loop" => {
                if spawns == Some(0) {
                    fails.push(format!(
                        "{label}: host-loop baseline reported 0 advance spawns — measurement is broken"
                    ));
                }
            }
            other => fails.push(format!("{label}: unknown mode {other:?}")),
        }
    }
}

/// Configuration fingerprint of a BENCH file — wall comparisons only make
/// sense between runs of the same workload shape.
fn config_key(doc: &Json) -> String {
    let mut parts = Vec::new();
    for key in ["bench", "case", "interior"] {
        parts.push(s(doc, key).to_string());
    }
    for key in ["steps", "segments", "threads", "rounds", "workers", "bt", "grid", "iters", "reps"] {
        parts.push(int(doc, key).map(|v| v.to_string()).unwrap_or_default());
    }
    parts.join("/")
}

/// Flatten a BENCH document into (entry-label, wall-seconds) gate points.
fn wall_entries(doc: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Some(modes) = doc.get("modes").and_then(Json::as_array) {
        for m in modes {
            if let Some(w) = num(m, "wall_seconds") {
                out.push((format!("{}/bt{}", s(m, "mode"), int(m, "bt").unwrap_or(1)), w));
            }
        }
    }
    if let Some(cases) = doc.get("cases").and_then(Json::as_array) {
        for c in cases {
            let label = format!("{}:{}", s(c, "case"), s(c, "interior"));
            if let Some(modes) = c.get("modes").and_then(Json::as_array) {
                for m in modes {
                    if let Some(w) = num(m, "wall_seconds") {
                        out.push((
                            format!("{label}/{}/bt{}", s(m, "mode"), int(m, "bt").unwrap_or(1)),
                            w,
                        ));
                    }
                }
            }
        }
    }
    if let Some(rows) = doc.get("rows").and_then(Json::as_array) {
        for r in rows {
            if let (Some(t), Some(w)) = (int(r, "tenants"), num(r, "farm_wall_seconds")) {
                out.push((format!("tenants{t}/farm"), w));
            }
            // plane rows: keyed by tenant count + front-end thread count
            if let (Some(t), Some(fe), Some(w)) =
                (int(r, "tenants"), int(r, "frontend_threads"), num(r, "wall_seconds"))
            {
                out.push((format!("tenants{t}/fe{fe}/plane"), w));
            }
            // cg_pipeline rows: keyed by system size + execution model
            if let (Some(n), Some(w)) = (int(r, "n"), num(r, "wall_seconds")) {
                if !s(r, "mode").is_empty() {
                    out.push((format!("n{n}/{}", s(r, "mode")), w));
                }
            }
            // resilience rows: keyed by case + checkpoint cadence, with a
            // `/durable` suffix on the durable-persistence arm
            if let (Some(cad), Some(w)) = (int(r, "cadence"), num(r, "wall_seconds")) {
                if !s(r, "case").is_empty() {
                    let durable =
                        if int(r, "durable").unwrap_or(0) == 1 { "/durable" } else { "" };
                    out.push((format!("{}/cad{cad}{durable}", s(r, "case")), w));
                }
            }
        }
    }
    out
}

fn check_file(cfg: &Config, name: &str, fails: &mut Vec<String>) {
    let path = cfg.dir.join(name);
    let doc = match load(&path) {
        Ok(d) => d,
        Err(e) => {
            fails.push(format!("{name}: missing or unreadable ({e}) — did the bench run?"));
            return;
        }
    };

    // ---- counter invariants (always) ----
    match s(&doc, "bench") {
        "stencil" => {
            let steps = int(&doc, "steps").unwrap_or(0);
            if let Some(modes) = doc.get("modes").and_then(Json::as_array) {
                check_modes(name, steps, modes, fails);
            } else {
                fails.push(format!("{name}: no modes array"));
            }
        }
        "temporal" => {
            let steps = int(&doc, "steps").unwrap_or(0);
            match doc.get("cases").and_then(Json::as_array) {
                Some(cases) => {
                    for c in cases {
                        let label = format!("{name}:{}", s(c, "case"));
                        match c.get("modes").and_then(Json::as_array) {
                            Some(modes) => check_modes(&label, steps, modes, fails),
                            None => fails.push(format!("{label}: no modes array")),
                        }
                    }
                }
                None => fails.push(format!("{name}: no cases array")),
            }
        }
        "farm" => match doc.get("rows").and_then(Json::as_array) {
            Some(rows) => {
                for r in rows {
                    let tenants = int(r, "tenants").unwrap_or(0);
                    if int(r, "admission_spawns") != Some(0) {
                        fails.push(format!(
                            "{name}: tenants={tenants} row spawned threads at admission (must be 0)"
                        ));
                    }
                    let speedup = num(r, "speedup").unwrap_or(0.0);
                    if tenants >= 16 && speedup < cfg.min_farm_speedup {
                        fails.push(format!(
                            "{name}: tenants={tenants} farm speedup {speedup:.2}x below the {:.2}x floor",
                            cfg.min_farm_speedup
                        ));
                    }
                }
            }
            None => fails.push(format!("{name}: no rows array")),
        },
        "plane" => match doc.get("rows").and_then(Json::as_array) {
            Some(rows) => {
                for r in rows {
                    let tenants = int(r, "tenants").unwrap_or(0);
                    let batches = int(r, "plane_batches");
                    let locks = int(r, "sched_lock_acquisitions");
                    if batches.is_none() || batches != locks {
                        fails.push(format!(
                            "{name}: tenants={tenants} row took {locks:?} scheduler locks for \
                             {batches:?} batches (batched path must be 1:1)"
                        ));
                    }
                    for key in ["plane_sheds", "plane_timeouts", "admission_spawns"] {
                        if int(r, key) != Some(0) {
                            fails.push(format!(
                                "{name}: tenants={tenants} row has nonzero {key} under quick load"
                            ));
                        }
                    }
                }
            }
            None => fails.push(format!("{name}: no rows array")),
        },
        "resilience" => match doc.get("rows").and_then(Json::as_array) {
            Some(rows) => {
                for r in rows {
                    let case = s(r, "case").to_string();
                    let cadence = int(r, "cadence").unwrap_or(0);
                    let injected = int(r, "injected").unwrap_or(0);
                    let recoveries = int(r, "recoveries");
                    let durable = int(r, "durable").unwrap_or(0) == 1;
                    if durable && cadence == 0 && injected == 0 {
                        if int(r, "durable_frames") != Some(0) {
                            fails.push(format!(
                                "{name}: cadence-0 durable row {case} committed {:?} frames \
                                 (durability off the cadence path must write nothing)",
                                int(r, "durable_frames")
                            ));
                        }
                        if int(r, "durable_bytes") != Some(0) {
                            fails.push(format!(
                                "{name}: cadence-0 durable row {case} wrote {:?} durable bytes \
                                 (must be 0)",
                                int(r, "durable_bytes")
                            ));
                        }
                    }
                    if durable && injected == 0 && int(r, "restores") != Some(0) {
                        fails.push(format!(
                            "{name}: clean durable row {case}/cad{cadence} reports {:?} \
                             snapshot restores (clean runs must restore 0 times)",
                            int(r, "restores")
                        ));
                    }
                    if injected == 0 && recoveries != Some(0) {
                        fails.push(format!(
                            "{name}: clean row {case}/cad{cadence} reports {recoveries:?} \
                             recoveries (must be 0 without injected faults)"
                        ));
                    }
                    if injected > 0 && recoveries.unwrap_or(0) == 0 {
                        fails.push(format!(
                            "{name}: recovery row {case} injected {injected} fault(s) but \
                             never recovered — injection or supervision is broken"
                        ));
                    }
                    if cadence == 0 && injected == 0 && int(r, "checkpoint_bytes") != Some(0) {
                        fails.push(format!(
                            "{name}: cadence-0 clean row {case} copied checkpoint bytes \
                             (cadence off must cost nothing)"
                        ));
                    }
                }
                // overhead gates: default cadence vs cadence 0, within
                // this artifact (same machine, same run). The in-memory
                // gate (5%) and the durable gate (10%) each compare
                // against their own cadence-0 reference arm.
                let wall_of = |case: &str, cadence: u64, durable: u64| {
                    rows.iter()
                        .filter(|r| {
                            s(r, "case") == case
                                && int(r, "cadence") == Some(cadence)
                                && int(r, "injected") == Some(0)
                                && int(r, "durable").unwrap_or(0) == durable
                        })
                        .find_map(|r| num(r, "wall_seconds"))
                };
                let mut cases: Vec<&str> = rows
                    .iter()
                    .filter(|r| int(r, "injected") == Some(0))
                    .map(|r| s(r, "case"))
                    .collect();
                cases.sort_unstable();
                cases.dedup();
                for case in cases {
                    for (durable, bar, what) in [
                        (0u64, MAX_CHECKPOINT_OVERHEAD, "default-cadence"),
                        (1u64, MAX_DURABLE_OVERHEAD, "default-cadence durable"),
                    ] {
                        let (Some(base), Some(walled)) = (
                            wall_of(case, 0, durable),
                            wall_of(case, perks::runtime::DEFAULT_CHECKPOINT_EVERY, durable),
                        ) else {
                            continue;
                        };
                        if base < OVERHEAD_GATE_MIN_WALL {
                            println!(
                                "note: {name}: {case} {what} cadence-0 wall {base:.6}s below \
                                 the {OVERHEAD_GATE_MIN_WALL}s noise floor; overhead gate skipped"
                            );
                            continue;
                        }
                        let limit = base * (1.0 + bar);
                        if walled > limit {
                            fails.push(format!(
                                "{name}: {case} {what} wall {walled:.6}s exceeds the \
                                 cadence-0 wall {base:.6}s by more than {:.0}%",
                                bar * 100.0
                            ));
                        }
                    }
                }
            }
            None => fails.push(format!("{name}: no rows array")),
        },
        "cg_pipeline" => match doc.get("rows").and_then(Json::as_array) {
            Some(rows) => {
                let iters = int(&doc, "iters").unwrap_or(0);
                for r in rows {
                    let n = int(r, "n").unwrap_or(0);
                    let mode = s(r, "mode");
                    if int(r, "advance_spawns") != Some(0) {
                        fails.push(format!(
                            "{name}: n={n} {mode} arm spawned threads per advance \
                             (both arms are resident pools; must be 0)"
                        ));
                    }
                    let want = match mode {
                        "pipelined" => Some(iters),
                        "persistent" => Some(2 * iters),
                        _ => None,
                    };
                    match want {
                        Some(w) => {
                            if int(r, "barrier_reductions") != Some(w) {
                                fails.push(format!(
                                    "{name}: n={n} {mode} arm paid {:?} barrier reductions \
                                     for {iters} iterations, expected exactly {w}",
                                    int(r, "barrier_reductions")
                                ));
                            }
                        }
                        None => fails.push(format!("{name}: unknown mode {mode:?}")),
                    }
                }
                // wall win: pipelined vs classic within this artifact
                // (same machine, same run)
                let wall_of = |n: u64, mode: &str| {
                    rows.iter()
                        .filter(|r| int(r, "n") == Some(n) && s(r, "mode") == mode)
                        .find_map(|r| num(r, "wall_seconds"))
                };
                let mut ns: Vec<u64> = rows.iter().filter_map(|r| int(r, "n")).collect();
                ns.sort_unstable();
                ns.dedup();
                for n in ns {
                    let (Some(classic), Some(pipe)) =
                        (wall_of(n, "persistent"), wall_of(n, "pipelined"))
                    else {
                        fails.push(format!("{name}: n={n} sweep is missing an arm"));
                        continue;
                    };
                    if classic < OVERHEAD_GATE_MIN_WALL {
                        println!(
                            "note: {name}: n={n} classic wall {classic:.6}s below the \
                             {OVERHEAD_GATE_MIN_WALL}s noise floor; wall-win gate skipped"
                        );
                        continue;
                    }
                    let limit = classic * (1.0 + MAX_PIPELINE_JITTER);
                    if pipe > limit {
                        fails.push(format!(
                            "{name}: n={n} pipelined wall {pipe:.6}s loses to classic \
                             {classic:.6}s by more than {:.0}% — the collapsed barrier \
                             must not regress the small-system sweep",
                            MAX_PIPELINE_JITTER * 100.0
                        ));
                    }
                }
            }
            None => fails.push(format!("{name}: no rows array")),
        },
        other => fails.push(format!("{name}: unknown bench kind {other:?}")),
    }

    // ---- wall-regression gate vs the checked-in baseline ----
    if cfg.no_wall || cfg.update {
        return;
    }
    let base_path = cfg.baseline_dir.join(name);
    let base = match load(&base_path) {
        Ok(b) => b,
        Err(e) => {
            println!("note: {name}: no baseline ({e}); wall gate skipped");
            return;
        }
    };
    if config_key(&doc) != config_key(&base) {
        println!(
            "note: {name}: workload config differs from baseline ({} vs {}); wall gate skipped",
            config_key(&doc),
            config_key(&base)
        );
        return;
    }
    let current = wall_entries(&doc);
    let baseline = wall_entries(&base);
    for (label, wall) in &current {
        let Some((_, base_wall)) = baseline.iter().find(|(l, _)| l == label) else {
            println!("note: {name}: baseline has no entry {label}; skipped");
            continue;
        };
        let limit = base_wall * (1.0 + cfg.tolerance);
        if *wall > limit {
            fails.push(format!(
                "{name}: {label} wall {wall:.6}s exceeds baseline {base_wall:.6}s by more than {:.0}%",
                cfg.tolerance * 100.0
            ));
        }
    }
}

fn update_baselines(cfg: &Config) -> Result<(), String> {
    std::fs::create_dir_all(&cfg.baseline_dir)
        .map_err(|e| format!("create {}: {e}", cfg.baseline_dir.display()))?;
    for name in FILES {
        let from = cfg.dir.join(name);
        let to = cfg.baseline_dir.join(name);
        std::fs::copy(&from, &to)
            .map_err(|e| format!("copy {} -> {}: {e}", from.display(), to.display()))?;
        println!("recorded {}", to.display());
    }
    Ok(())
}

fn main() -> ExitCode {
    let cfg = match parse_args() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench_check: {e}");
            return ExitCode::FAILURE;
        }
    };
    let mut fails = Vec::new();
    for name in FILES {
        check_file(&cfg, name, &mut fails);
    }
    if fails.is_empty() && cfg.update {
        if let Err(e) = update_baselines(&cfg) {
            eprintln!("bench_check: {e}");
            return ExitCode::FAILURE;
        }
    }
    if fails.is_empty() {
        println!(
            "bench_check: OK ({} files, tolerance {:.0}%, farm floor {:.2}x{})",
            FILES.len(),
            cfg.tolerance * 100.0,
            cfg.min_farm_speedup,
            if cfg.no_wall { ", wall gate off" } else { "" }
        );
        ExitCode::SUCCESS
    } else {
        for f in &fails {
            eprintln!("FAIL: {f}");
        }
        eprintln!("bench_check: {} failure(s)", fails.len());
        ExitCode::FAILURE
    }
}
