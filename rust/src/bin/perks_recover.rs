//! `perks_recover` — list, verify, and resume durable snapshot
//! directories written by `runtime::resilience::snapshot::SnapshotStore`
//! (see `docs/RECOVERY.md` for the on-disk layout and the
//! crash-consistency argument).
//!
//! ```text
//! perks_recover list <dir>                  # tenants + generations
//! perks_recover verify <dir>                # checksum every frame
//! perks_recover resume <dir> [--workers N]  # finish interrupted commands
//! perks_recover crash-demo <dir> [--workers N] [--case C]
//! ```
//!
//! `resume` rebuilds each tenant from its self-describing
//! [`WorkloadMeta`], restores the newest generation that verifies
//! (falling back past torn frames), finishes the command the snapshot
//! was taken in, and prints a bit-level fingerprint of the final state.
//!
//! `crash-demo` is the end-to-end acceptance drill CI's `crash-restart`
//! job runs: for each workload case it computes an uninterrupted
//! reference in-process, re-executes itself as a child process that runs
//! the same workload with durable checkpoints and a `FaultKind::Kill`
//! fault (a hard `process::abort` mid-`advance` — the SIGKILL stand-in),
//! asserts the child died abnormally, restores from the snapshot
//! directory the child left behind, resumes the remaining epochs, and
//! requires the final state to match the reference **bit for bit**.
//! Cases: `stencil2d` (2d5pt, bt=2), `stencil3d` (3d7pt, bt=2), `cg`
//! (Poisson), or `all` (default).

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;
use std::time::{Duration, Instant};

use perks::runtime::farm::SolverFarm;
use perks::runtime::{
    FaultPlan, FaultSpec, ResilienceConfig, Restored, SnapshotStore, WorkloadMeta,
};
use perks::sparse::gen;
use perks::spmv::merge::MergePlan;
use perks::stencil::{spec, Domain};
use perks::util::codec::{fnv1a64, Encoder};
use perks::{Error, Result};

const USAGE: &str = "usage: perks_recover <list|verify|resume|crash-demo|crash-child> <dir> \
                     [--workers N] [--case stencil2d|stencil3d|cg|all]";

/// One crash-demo workload: two commands (`s1` then `s2`), a kill fault
/// pinned mid-command-2, and a checkpoint cadence that guarantees
/// durable frames exist before the kill epoch.
struct DemoCase {
    name: &'static str,
    /// `None` = CG over the Poisson operator; `Some` = stencil bench.
    stencil: Option<(&'static str, &'static [usize], usize)>, // (bench, interior, bt)
    cg_grid: usize,
    shards: usize,
    s1: usize,
    s2: usize,
    kill_epoch: u64,
    cadence: u64,
    seed: u64,
}

const CASES: [DemoCase; 3] = [
    DemoCase {
        name: "stencil2d",
        stencil: Some(("2d5pt", &[16, 16], 2)),
        cg_grid: 0,
        shards: 3,
        s1: 8,
        s2: 8,
        kill_epoch: 6,
        cadence: 2,
        seed: 2026,
    },
    DemoCase {
        name: "stencil3d",
        stencil: Some(("3d7pt", &[6, 6, 6], 2)),
        cg_grid: 0,
        shards: 3,
        s1: 8,
        s2: 8,
        kill_epoch: 6,
        cadence: 2,
        seed: 2027,
    },
    DemoCase {
        name: "cg",
        stencil: None,
        cg_grid: 12,
        shards: 3,
        s1: 8,
        s2: 8,
        kill_epoch: 12,
        cadence: 3,
        seed: 7,
    },
];

fn case_named(name: &str) -> Option<&'static DemoCase> {
    CASES.iter().find(|c| c.name == name)
}

/// Bit-level fingerprint of a state vector (FNV-1a 64 over the exact
/// f64 bytes — two states print the same fingerprint iff bit-identical).
fn fingerprint(state: &[f64]) -> u64 {
    let mut e = Encoder::with_capacity(state.len() * 8);
    e.put_f64s(state);
    fnv1a64(&e.finish())
}

struct Args {
    cmd: String,
    dir: PathBuf,
    workers: usize,
    case: String,
}

fn parse_args() -> std::result::Result<Args, String> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().ok_or(USAGE)?;
    let dir = PathBuf::from(it.next().ok_or(USAGE)?);
    let mut args = Args { cmd, dir, workers: 2, case: "all".into() };
    while let Some(tok) = it.next() {
        match tok.as_str() {
            "--workers" => {
                args.workers = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .filter(|&w| w > 0)
                    .ok_or("--workers needs a positive integer")?;
            }
            "--case" => args.case = it.next().ok_or("--case needs a value")?,
            other => return Err(format!("unknown flag {other:?}\n{USAGE}")),
        }
    }
    Ok(args)
}

fn cmd_list(store: &SnapshotStore) -> Result<()> {
    let tenants = store.tenants()?;
    if tenants.is_empty() {
        println!("{}: no tenants", store.root().display());
        return Ok(());
    }
    for t in tenants {
        let entries = store.entries(&t)?;
        // peek the newest restorable generation for the workload line
        let desc = store
            .restore(&t)
            .map(|r| r.meta.describe())
            .unwrap_or_else(|e| format!("unrestorable: {e}"));
        println!("{t}: {desc}");
        for e in entries {
            println!(
                "  gen {:>4}  epoch {:>6}  {:>9} B  checksum {:016x}",
                e.generation, e.epoch, e.frame_len, e.checksum
            );
        }
    }
    Ok(())
}

fn cmd_verify(store: &SnapshotStore) -> Result<bool> {
    let mut all_ok = true;
    for t in store.tenants()? {
        for st in store.verify(&t)? {
            match st.problem {
                None => println!("{t} gen {} epoch {}: ok", st.generation, st.epoch),
                Some(p) => {
                    all_ok = false;
                    println!("{t} gen {} epoch {}: FAIL {p}", st.generation, st.epoch);
                }
            }
        }
    }
    Ok(all_ok)
}

/// Rebuild the tenant a restored frame describes on a fresh farm and
/// finish the command the snapshot was taken in. Returns the final
/// state vector (stencil grid or CG iterate).
fn resume_tenant(farm: &SolverFarm, restored: &Restored) -> Result<Vec<f64>> {
    let ck = &restored.checkpoint;
    let (done, target) = ck.progress();
    let remaining = target.saturating_sub(done);
    match &restored.meta {
        WorkloadMeta::Stencil { bench, dims, bt, shards } => {
            let s = spec(bench)
                .ok_or_else(|| Error::Snapshot(format!("unknown stencil bench {bench:?}")))?;
            let d = Domain::for_spec(&s, dims)?;
            let mut t = farm.handle().admit_stencil(&s, &d, *shards, *bt)?;
            t.restore_from(ck)?;
            if remaining > 0 {
                t.advance(remaining, None)?;
            }
            t.state()
        }
        WorkloadMeta::Cg { n, shards } => {
            let grid = (*n as f64).sqrt().round() as usize;
            if grid * grid != *n {
                return Err(Error::Snapshot(format!(
                    "cannot rebuild a non-square CG system (n = {n}); resume it from the \
                     owning application via Checkpoint::cg_state"
                )));
            }
            let a = Arc::new(gen::poisson2d(grid));
            let plan = MergePlan::new(&a, *shards);
            let mut t = farm.handle().admit_cg(a, plan)?;
            let (mut x, mut r, mut p, rr, _) = ck
                .cg_state()
                .ok_or_else(|| Error::Snapshot("CG meta with a stencil payload".into()))?;
            if remaining > 0 {
                let run = t.run(&mut x, &mut r, &mut p, rr, 0.0, remaining)?;
                if let Some(msg) = run.error {
                    return Err(Error::Solver(msg));
                }
            }
            Ok(x)
        }
    }
}

fn cmd_resume(store: &SnapshotStore, workers: usize) -> Result<()> {
    let tenants = store.tenants()?;
    if tenants.is_empty() {
        return Err(Error::Snapshot(format!(
            "{}: no tenants to resume",
            store.root().display()
        )));
    }
    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(FaultPlan::new()); // hermetic: recovery never re-injects
    for t in tenants {
        let restored = store.restore(&t)?;
        let (done, target) = restored.checkpoint.progress();
        println!(
            "{t}: {} @ gen {} epoch {} ({}{} of command {done}/{target})",
            restored.meta.describe(),
            restored.generation,
            restored.checkpoint.epoch,
            if restored.fallbacks > 0 { "fell back " } else { "newest frame, " },
            if restored.fallbacks > 0 {
                format!("{} generation(s)", restored.fallbacks)
            } else {
                "resuming".into()
            },
        );
        let state = resume_tenant(&farm, &restored)?;
        println!("{t}: resumed to completion; state fingerprint {:016x}", fingerprint(&state));
    }
    Ok(())
}

/// Uninterrupted in-process reference run of one demo case (clean farm,
/// empty fault plan): the bits the crashed-and-resumed run must land on.
fn reference_state(case: &DemoCase, workers: usize) -> Result<Vec<f64>> {
    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(FaultPlan::new());
    match case.stencil {
        Some((bench, interior, bt)) => {
            let s = spec(bench)
                .ok_or_else(|| Error::invalid(format!("unknown stencil bench {bench:?}")))?;
            let mut d = Domain::for_spec(&s, interior)?;
            d.randomize(case.seed);
            let mut t = farm.handle().admit_stencil(&s, &d, case.shards, bt)?;
            t.advance(case.s1 + case.s2, None)?;
            t.state()
        }
        None => {
            let a = Arc::new(gen::poisson2d(case.cg_grid));
            let b = gen::rhs(a.n_rows, case.seed);
            let plan = MergePlan::new(&a, case.shards);
            let rr0: f64 = b.iter().map(|v| v * v).sum();
            let mut t = farm.handle().admit_cg(a.clone(), plan)?;
            let (mut x, mut r, mut p) = (vec![0.0; a.n_rows], b.clone(), b);
            let run = t.run(&mut x, &mut r, &mut p, rr0, 0.0, case.s1 + case.s2)?;
            if let Some(msg) = run.error {
                return Err(Error::Solver(msg));
            }
            Ok(x)
        }
    }
}

/// The child half of `crash-demo`: run the case's workload with durable
/// checkpoints and a pinned `FaultKind::Kill`, and die mid-command-2.
/// Command 1 runs clean; the child then *waits until at least one frame
/// is committed on disk* before issuing the doomed command, so the
/// parent's restore can never race the off-lock write-out.
fn cmd_crash_child(dir: &Path, case: &DemoCase, workers: usize) -> Result<()> {
    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(
        FaultPlan::new().inject(FaultSpec::kill_at(case.kill_epoch).tenant(0)),
    );
    let cfg = ResilienceConfig::disabled().every(case.cadence).durable(dir);
    let store = SnapshotStore::open(dir)?;
    let wait_for_frame = || -> Result<()> {
        let t0 = Instant::now();
        while store.entries("t0").map(|e| e.is_empty()).unwrap_or(true) {
            if t0.elapsed() > Duration::from_secs(10) {
                return Err(Error::Snapshot(
                    "no durable frame appeared within 10s of the clean command".into(),
                ));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    };
    match case.stencil {
        Some((bench, interior, bt)) => {
            let s = spec(bench)
                .ok_or_else(|| Error::invalid(format!("unknown stencil bench {bench:?}")))?;
            let mut d = Domain::for_spec(&s, interior)?;
            d.randomize(case.seed);
            let mut t = farm.handle().admit_stencil(&s, &d, case.shards, bt)?;
            t.configure_resilience(cfg)?;
            t.advance(case.s1, None)?;
            wait_for_frame()?;
            t.advance(case.s2, None)?; // aborts at kill_epoch: never returns
        }
        None => {
            let a = Arc::new(gen::poisson2d(case.cg_grid));
            let b = gen::rhs(a.n_rows, case.seed);
            let plan = MergePlan::new(&a, case.shards);
            let rr0: f64 = b.iter().map(|v| v * v).sum();
            let mut t = farm.handle().admit_cg(a.clone(), plan)?;
            t.configure_resilience(cfg)?;
            let (mut x, mut r, mut p) = (vec![0.0; a.n_rows], b.clone(), b);
            let run1 = t.run(&mut x, &mut r, &mut p, rr0, 0.0, case.s1)?;
            if let Some(msg) = run1.error {
                return Err(Error::Solver(msg));
            }
            wait_for_frame()?;
            t.run(&mut x, &mut r, &mut p, run1.rr, 0.0, case.s2)?; // aborts
        }
    }
    Err(Error::Solver(
        "crash-child survived its kill fault — the injection never fired".into(),
    ))
}

/// The parent half of `crash-demo` for one case: reference run, child
/// crash, restore, resume, bit-compare.
fn crash_demo_case(exe: &Path, dir: &Path, case: &DemoCase, workers: usize) -> Result<()> {
    let case_dir = dir.join(case.name);
    let _ = std::fs::remove_dir_all(&case_dir); // fresh directory per drill
    let want = reference_state(case, workers)?;

    let status = std::process::Command::new(exe)
        .arg("crash-child")
        .arg(&case_dir)
        .arg("--case")
        .arg(case.name)
        .arg("--workers")
        .arg(workers.to_string())
        .status()
        .map_err(|e| Error::Solver(format!("spawning crash child: {e}")))?;
    if status.success() {
        return Err(Error::Solver(format!(
            "{}: crash child exited cleanly — the kill fault never fired",
            case.name
        )));
    }

    let store = SnapshotStore::open(&case_dir)?;
    let restored = store.restore("t0")?;
    // global progress: stencil epochs each cover bt steps, CG epochs are
    // iterations — either way `epoch * unit` steps of the total are done
    let unit = case.stencil.map(|(_, _, bt)| bt).unwrap_or(1);
    let total = case.s1 + case.s2;
    let done = restored.checkpoint.epoch as usize * unit;
    if done == 0 || done >= total {
        return Err(Error::Snapshot(format!(
            "{}: restored epoch {} implies {done}/{total} steps done — outside the crash window",
            case.name, restored.checkpoint.epoch
        )));
    }

    let farm = SolverFarm::spawn(workers)?;
    farm.install_faults(FaultPlan::new());
    let got = match &restored.meta {
        WorkloadMeta::Stencil { bench, dims, bt, shards } => {
            let s = spec(bench)
                .ok_or_else(|| Error::Snapshot(format!("unknown stencil bench {bench:?}")))?;
            let d = Domain::for_spec(&s, dims)?;
            let mut t = farm.handle().admit_stencil(&s, &d, *shards, *bt)?;
            t.restore_from(&restored.checkpoint)?;
            t.advance(total - done, None)?;
            t.state()?
        }
        WorkloadMeta::Cg { shards, .. } => {
            let a = Arc::new(gen::poisson2d(case.cg_grid));
            let plan = MergePlan::new(&a, *shards);
            let mut t = farm.handle().admit_cg(a, plan)?;
            let (mut x, mut r, mut p, rr, _) = restored
                .checkpoint
                .cg_state()
                .ok_or_else(|| Error::Snapshot("CG meta with a stencil payload".into()))?;
            let run = t.run(&mut x, &mut r, &mut p, rr, 0.0, total - done)?;
            if let Some(msg) = run.error {
                return Err(Error::Solver(msg));
            }
            x
        }
    };

    let identical =
        got.len() == want.len() && got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
    if !identical {
        return Err(Error::Solver(format!(
            "{}: resumed state diverged from the uninterrupted reference \
             (fingerprints {:016x} vs {:016x})",
            case.name,
            fingerprint(&got),
            fingerprint(&want)
        )));
    }
    println!(
        "{}: killed at epoch {} -> restored gen {} (epoch {}, {} fallback(s)) -> resumed \
         {} steps -> bit-identical (fingerprint {:016x}, workers={workers})",
        case.name,
        case.kill_epoch,
        restored.generation,
        restored.checkpoint.epoch,
        restored.fallbacks,
        total - done,
        fingerprint(&got),
    );
    Ok(())
}

fn cmd_crash_demo(dir: &Path, which: &str, workers: usize) -> Result<()> {
    let exe = std::env::current_exe()
        .map_err(|e| Error::Solver(format!("cannot locate own executable: {e}")))?;
    let cases: Vec<&DemoCase> = if which == "all" {
        CASES.iter().collect()
    } else {
        vec![case_named(which)
            .ok_or_else(|| Error::invalid(format!("unknown crash-demo case {which:?}")))?]
    };
    for case in cases {
        crash_demo_case(&exe, dir, case, workers)?;
    }
    println!("crash-demo: every case resumed bit-identically after process death");
    Ok(())
}

fn run(args: &Args) -> Result<bool> {
    match args.cmd.as_str() {
        "list" => {
            cmd_list(&SnapshotStore::open(&args.dir)?)?;
            Ok(true)
        }
        "verify" => cmd_verify(&SnapshotStore::open(&args.dir)?),
        "resume" => {
            cmd_resume(&SnapshotStore::open(&args.dir)?, args.workers)?;
            Ok(true)
        }
        "crash-demo" => {
            cmd_crash_demo(&args.dir, &args.case, args.workers)?;
            Ok(true)
        }
        "crash-child" => {
            let case = case_named(&args.case)
                .ok_or_else(|| Error::invalid(format!("unknown case {:?}", args.case)))?;
            cmd_crash_child(&args.dir, case, args.workers)?;
            Ok(true)
        }
        other => Err(Error::invalid(format!("unknown subcommand {other:?}\n{USAGE}"))),
    }
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("perks_recover: {msg}");
            return ExitCode::from(2);
        }
    };
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("perks_recover: {e}");
            ExitCode::FAILURE
        }
    }
}
