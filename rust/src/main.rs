//! `perks` CLI — the leader entrypoint.
//!
//! Subcommands (no external CLI crate in the vendored set; parsing is
//! hand-rolled in `args`):
//!
//! * `info`                      — platform + artifact inventory
//! * `run-stencil [--bench ..]`  — execute a stencil through PJRT under all
//!                                 execution models and compare
//! * `run-cg [--n ..]`           — execute CG through PJRT
//! * `simulate <figN|tableN>`    — regenerate a paper table/figure
//! * `cpu-perks [--bench ..]`    — persistent-threads CPU demonstration

use perks::coordinator::{CgDriver, ExecMode, StencilDriver};
use perks::harness;
use perks::runtime::{HostTensor, Runtime};
use perks::simgpu::device;
use perks::sparse::gen;
use perks::stencil::{self, parallel};
use perks::util::fmt::{self, Table};
use perks::{Error, Result};

/// Minimal `--key value` argument map.
struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = std::collections::HashMap::new();
        let mut key: Option<String> = None;
        for a in it {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(k) = key.take() {
                    flags.insert(k, "true".into());
                }
                key = Some(stripped.to_string());
            } else if let Some(k) = key.take() {
                flags.insert(k, a);
            }
        }
        if let Some(k) = key.take() {
            flags.insert(k, "true".into());
        }
        Args { cmd, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn int(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn main() {
    let args = Args::parse();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "info" => info(args),
        "run-stencil" => run_stencil(args),
        "run-cg" => run_cg(args),
        "simulate" => simulate(args),
        "cpu-perks" => cpu_perks(args),
        "advise" => advise(args),
        "tune" => tune(args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::invalid(format!("unknown command {other:?} (try `perks help`)"))),
    }
}

fn print_help() {
    println!(
        "perks — persistent-kernel execution model (paper reproduction)\n\
         \n\
         USAGE: perks <command> [--flag value ...]\n\
         \n\
         COMMANDS:\n\
         \x20 info                               platform + artifact inventory\n\
         \x20 run-stencil  --bench 2d5pt --interior 128x128 --dtype f32 --steps 64\n\
         \x20 run-cg       --n 1024 --iters 64\n\
         \x20 cpu-perks    --bench 2d5pt --size 512 --steps 64 --threads 8\n\
         \x20 simulate     <fig5|fig6|fig7|fig8|fig9> --device A100\n\
         \x20 advise       --solver cg --n 150000 --nnz 1000000 --device A100\n\
         \x20 tune         --bench 2d5pt --size 256 (CPU thread autotune)\n\
         \n\
         Artifacts are read from $PERKS_ARTIFACTS or ./artifacts (run\n\
         `make artifacts` first)."
    );
}

fn info(_args: &Args) -> Result<()> {
    let rt = Runtime::new(Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("artifact dir: {}", rt.artifact_dir().display());
    let mut t = Table::new(&["name", "kind", "inputs", "outputs"]);
    for a in &rt.manifest.artifacts {
        let ins: Vec<String> = a.inputs.iter().map(|s| s.to_string()).collect();
        let outs: Vec<String> = a.outputs.iter().map(|s| s.to_string()).collect();
        t.row(&[a.name.clone(), a.kind.clone(), ins.join(","), outs.join(",")]);
    }
    print!("{}", t.render());
    Ok(())
}

fn run_stencil(args: &Args) -> Result<()> {
    let bench = args.get("bench", "2d5pt");
    let interior = args.get("interior", "128x128");
    let dtype = args.get("dtype", "f32");
    let steps = args.int("steps", 64);

    let rt = Runtime::new(Runtime::default_dir())?;
    let driver = StencilDriver::new(&rt, &bench, &interior, &dtype)?;
    let spec = stencil::spec(&bench).ok_or_else(|| Error::invalid("unknown bench"))?;
    let dims: Vec<usize> =
        interior.split('x').map(|d| d.parse().unwrap()).collect();
    let mut dom = stencil::Domain::for_spec(&spec, &dims)?;
    dom.randomize(42);
    let x0 = match dtype.as_str() {
        "f64" => HostTensor::f64(&padded_dims(&dom), dom.data.clone()),
        _ => HostTensor::f32(&padded_dims(&dom), dom.to_f32()),
    };

    println!(
        "stencil {bench} interior {interior} dtype {dtype} steps {steps} (fused {})",
        driver.fused_steps
    );
    let mut t = Table::new(&["mode", "wall", "GCells/s", "launches", "host bytes"]);
    let mut reference: Option<Vec<f64>> = None;
    for mode in ExecMode::all() {
        let report = driver.run(mode, &x0, steps)?;
        let state = report.state[0].to_f64_vec()?;
        match &reference {
            None => reference = Some(state),
            Some(r) => {
                let max_diff = r
                    .iter()
                    .zip(&state)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f64::max);
                if max_diff > 1e-4 {
                    return Err(Error::Solver(format!(
                        "{}: diverged from host-loop by {max_diff}",
                        mode.name()
                    )));
                }
            }
        }
        t.row(&[
            mode.name().to_string(),
            fmt::secs(report.wall_seconds),
            fmt::gcells(report.cells_per_sec(driver.interior_cells())),
            report.invocations.to_string(),
            fmt::bytes(report.host_bytes as f64),
        ]);
    }
    print!("{}", t.render());
    println!("all modes agree numerically ✓");
    Ok(())
}

fn padded_dims(dom: &stencil::Domain) -> Vec<usize> {
    if dom.interior[0] == 1 {
        vec![dom.padded[1], dom.padded[2]]
    } else {
        dom.padded.to_vec()
    }
}

fn run_cg(args: &Args) -> Result<()> {
    let n = args.int("n", 1024);
    let iters = args.int("iters", 64);
    let g = (n as f64).sqrt() as usize;

    let rt = Runtime::new(Runtime::default_dir())?;
    let driver = CgDriver::new(&rt, n)?;
    let a = gen::poisson2d(g);
    if a.nnz() != driver.nnz {
        return Err(Error::invalid(format!(
            "generated nnz {} != artifact nnz {}",
            a.nnz(),
            driver.nnz
        )));
    }
    let (data, cols, rows) = a.to_coo_f32();
    let data = HostTensor::f32(&[driver.nnz], data);
    let cols = HostTensor::i32(&[driver.nnz], cols);
    let rows = HostTensor::i32(&[driver.nnz], rows);
    let b: Vec<f32> = gen::rhs(n, 7).iter().map(|&v| v as f32).collect();

    println!("cg n={n} nnz={} iters={iters} (fused {})", driver.nnz, driver.fused_iters);
    let mut t = Table::new(&["mode", "wall", "iters/s", "launches", "rr_final", "true ||b-Ax||^2"]);
    for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
        let rep = driver.run(mode, &data, &cols, &rows, &b, iters)?;
        let resid = driver.residual(&data, &cols, &rows, &rep.x, &b)?;
        t.row(&[
            mode.name().to_string(),
            fmt::secs(rep.wall_seconds),
            format!("{:.0}", rep.iters as f64 / rep.wall_seconds),
            rep.invocations.to_string(),
            format!("{:.3e}", rep.rr),
            format!("{resid:.3e}"),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cpu_perks(args: &Args) -> Result<()> {
    let bench = args.get("bench", "2d5pt");
    let size = args.int("size", 512);
    let steps = args.int("steps", 64);
    let threads = args.int("threads", 8);
    let spec = stencil::spec(&bench).ok_or_else(|| Error::invalid("unknown bench"))?;
    let interior: Vec<usize> =
        if spec.dims == 2 { vec![size, size] } else { vec![size, size, size] };
    let mut dom = stencil::Domain::for_spec(&spec, &interior)?;
    dom.randomize(1);

    println!("cpu persistent-threads demo: {bench} {size}^{} steps={steps} threads={threads}", spec.dims);
    let h = parallel::host_loop(&spec, &dom, steps, threads)?;
    let p = parallel::persistent(&spec, &dom, steps, threads)?;
    let diff = h.result.max_abs_diff(&p.result);
    let mut t = Table::new(&["mode", "wall", "GCells/s", "global traffic", "barrier wait"]);
    let cells = dom.interior_cells() as f64 * steps as f64;
    t.row(&[
        "host-loop".into(),
        fmt::secs(h.wall_seconds),
        fmt::gcells(cells / h.wall_seconds),
        fmt::bytes(h.global_bytes as f64),
        "-".into(),
    ]);
    t.row(&[
        "persistent (PERKS)".into(),
        fmt::secs(p.wall_seconds),
        fmt::gcells(cells / p.wall_seconds),
        fmt::bytes(p.global_bytes as f64),
        fmt::secs(p.barrier_wait.as_secs_f64()),
    ]);
    print!("{}", t.render());
    println!("speedup: {:.2}x   max diff: {diff:.2e}", h.wall_seconds / p.wall_seconds);
    Ok(())
}

fn advise(args: &Args) -> Result<()> {
    use perks::coordinator::profile;
    let dev_name = args.get("device", "A100");
    let dev = device::by_name(&dev_name)
        .ok_or_else(|| Error::invalid(format!("unknown device {dev_name:?}")))?;
    let solver = args.get("solver", "cg");
    let profile = match solver.as_str() {
        "cg" => {
            let n = args.int("n", 150_000);
            let nnz = args.int("nnz", 1_000_000);
            profile::profile_cg(n, nnz, 4, 10)
        }
        "stencil" => {
            let interior = args.int("cells", 3072 * 3072) as u64 * 4;
            profile::profile_stencil(interior, interior / 24, 10)
        }
        other => return Err(Error::invalid(format!("unknown solver {other:?}"))),
    };
    // capacity at minimum occupancy for a lean kernel
    let kr = perks::simgpu::KernelResources {
        threads_per_tb: 256,
        regs_per_thread: 40,
        smem_per_tb: 2048,
    };
    let occ = perks::simgpu::occupancy(&dev, &kr, 1)
        .ok_or_else(|| Error::invalid("kernel does not fit"))?;
    print!(
        "{}",
        profile.report(
            occ.free_smem_bytes_device(&dev) as f64,
            occ.free_reg_bytes_device(&dev) as f64 * 0.73
        )
    );
    Ok(())
}

fn tune(args: &Args) -> Result<()> {
    use perks::coordinator::autotune;
    let bench = args.get("bench", "2d5pt");
    let size = args.int("size", 256);
    let spec = stencil::spec(&bench).ok_or_else(|| Error::invalid("unknown bench"))?;
    let interior: Vec<usize> =
        if spec.dims == 2 { vec![size, size] } else { vec![size, size, size] };
    let mut dom = stencil::Domain::for_spec(&spec, &interior)?;
    dom.randomize(1);
    let max = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let choice = autotune::tune_threads(&spec, &dom, 8, max)?;
    println!("measured thread sweep ({bench}, {size}^{}):", spec.dims);
    for (t, s) in &choice.sweep {
        let marker = if *t == choice.threads { "  <- best" } else { "" };
        println!("  {t:>3} threads: {}{marker}", fmt::secs(*s));
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let what = args.get("figure", "").to_string();
    let what = if what.is_empty() {
        // positional: `perks simulate fig5 --device A100` puts fig5 as a
        // dangling flag-less token we stored nowhere; accept via --figure
        // or first flagless arg handled here:
        std::env::args().nth(2).unwrap_or_default()
    } else {
        what
    };
    let dev_name = args.get("device", "A100");
    let dev = device::by_name(&dev_name)
        .ok_or_else(|| Error::invalid(format!("unknown device {dev_name:?}")))?;
    let elem = if args.get("dtype", "f64") == "f32" { 4 } else { 8 };
    let devs = [device::a100(), device::v100()];
    match what.as_str() {
        "fig5" => print!("{}", harness::render_stencil_speedups(&devs, elem, false)),
        "fig6" => print!("{}", harness::render_stencil_speedups(&devs, elem, true)),
        "fig7" => print!("{}", harness::render_fig7(&dev, elem)),
        "fig8" => print!("{}", harness::render_fig8(&dev, elem)),
        "fig9" => print!("{}", harness::render_fig9(&dev, elem)),
        other => {
            return Err(Error::invalid(format!(
                "unknown simulation {other:?}; fig1/fig2/table2/table4 live in `cargo bench`"
            )))
        }
    }
    Ok(())
}
