//! `perks` CLI — the leader entrypoint.
//!
//! Every workload-running subcommand goes through the unified
//! `perks::session` API (one builder, pluggable backends), and argument
//! parsing is the typed closed-set parser in `util::args` (unknown flags
//! and bad values are errors, not silent drops):
//!
//! * `info`                      — platform + artifact inventory
//! * `run-stencil [--bench ..]`  — a stencil through the PJRT backend
//!                                 under one/all/auto execution models
//! * `run-cg [--n ..]`           — CG through the PJRT backend
//! * `cpu-perks [--bench ..]`    — the CPU persistent-threads backend
//! * `simulate <figN>`           — regenerate a paper table/figure
//! * `advise` / `tune`           — capacity advisor / thread autotuner

use std::rc::Rc;

use perks::harness;
use perks::runtime::Runtime;
use perks::session::{Backend, ExecMode, ExecPolicy, SessionBuilder};
use perks::simgpu::device;
use perks::stencil;
use perks::util::args::ParsedArgs;
use perks::util::fmt::{self, Table};
use perks::{Error, Result};

fn main() {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".into());
    let rest: Vec<String> = argv.collect();
    let code = match run(&cmd, rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, rest: Vec<String>) -> Result<()> {
    match cmd {
        "info" => info(ParsedArgs::parse(cmd, rest, &[], 0)?),
        "run-stencil" => run_stencil(ParsedArgs::parse(
            cmd,
            rest,
            &["bench", "interior", "dtype", "steps", "mode", "seed"],
            0,
        )?),
        "run-cg" => run_cg(ParsedArgs::parse(cmd, rest, &["n", "iters", "mode"], 0)?),
        "simulate" => simulate(ParsedArgs::parse(cmd, rest, &["figure", "device", "dtype"], 1)?),
        "cpu-perks" => cpu_perks(ParsedArgs::parse(
            cmd,
            rest,
            &["bench", "size", "steps", "threads", "mode"],
            0,
        )?),
        "advise" => advise(ParsedArgs::parse(cmd, rest, &["device", "solver", "n", "nnz", "cells"], 0)?),
        "tune" => tune(ParsedArgs::parse(cmd, rest, &["bench", "size"], 0)?),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::invalid(format!("unknown command {other:?} (try `perks help`)"))),
    }
}

fn print_help() {
    println!(
        "perks — persistent-kernel execution model (paper reproduction)\n\
         \n\
         USAGE: perks <command> [--flag value ...]\n\
         \n\
         COMMANDS:\n\
         \x20 info                               platform + artifact inventory\n\
         \x20 run-stencil  --bench 2d5pt --interior 128x128 --dtype f32 --steps 64\n\
         \x20              --mode all|auto|host-loop|resident|persistent\n\
         \x20 run-cg       --n 1024 --iters 64 --mode all|auto|host-loop|persistent\n\
         \x20 cpu-perks    --bench 2d5pt --size 512 --steps 64 --threads 8 (0 = auto)\n\
         \x20 simulate     <fig5|fig6|fig7|fig8|fig9> --device A100\n\
         \x20 advise       --solver cg --n 150000 --nnz 1000000 --device A100\n\
         \x20 tune         --bench 2d5pt --size 256 (CPU thread autotune)\n\
         \n\
         Unknown flags are errors (closed per-command flag sets).\n\
         Artifacts are read from $PERKS_ARTIFACTS or ./artifacts (run\n\
         `make artifacts` first)."
    );
}

/// Resolve a `--mode` flag into the session policies to run.
fn policies(flag: &str, modes: &[ExecMode]) -> Result<Vec<ExecPolicy>> {
    match flag {
        "all" => Ok(modes.iter().map(|&m| ExecPolicy::Fixed(m)).collect()),
        "auto" => Ok(vec![ExecPolicy::Auto]),
        other => ExecMode::parse(other)
            .map(|m| vec![ExecPolicy::Fixed(m)])
            .ok_or_else(|| {
                Error::invalid(format!(
                    "unknown mode {other:?} (all, auto, host-loop, resident, persistent)"
                ))
            }),
    }
}

fn info(_args: ParsedArgs) -> Result<()> {
    let rt = Runtime::new(Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("artifact dir: {}", rt.artifact_dir().display());
    let mut t = Table::new(&["name", "kind", "inputs", "outputs"]);
    for a in &rt.manifest.artifacts {
        let ins: Vec<String> = a.inputs.iter().map(|s| s.to_string()).collect();
        let outs: Vec<String> = a.outputs.iter().map(|s| s.to_string()).collect();
        t.row(&[a.name.clone(), a.kind.clone(), ins.join(","), outs.join(",")]);
    }
    print!("{}", t.render());
    Ok(())
}

fn run_stencil(args: ParsedArgs) -> Result<()> {
    let bench = args.get("bench", "2d5pt");
    let interior = args.get("interior", "128x128");
    let dtype = args.get("dtype", "f32");
    let steps = args.get_usize("steps", 64)?;
    let seed = args.get_usize("seed", 42)? as u64;
    // pipelined is CG-only: `--mode all` sweeps the three stencil models
    let policies = policies(
        &args.get("mode", "all"),
        &[ExecMode::HostLoop, ExecMode::HostLoopResident, ExecMode::Persistent],
    )?;

    let rt = Rc::new(Runtime::new(Runtime::default_dir())?);
    // build every session first so one step count (aligned to the deepest
    // fused chunk) serves all modes — the states must stay comparable
    let mut sessions = Vec::new();
    for policy in policies {
        let session = SessionBuilder::stencil(&bench, &interior, &dtype)
            .backend(Backend::pjrt(rt.clone()))
            .policy(policy)
            .seed(seed)
            .build()?;
        sessions.push((policy, session));
    }
    let chunk = sessions.iter().map(|(_, s)| s.fused_chunk()).max().unwrap_or(1);
    let run_steps =
        sessions.iter().map(|(_, s)| s.aligned_steps(steps)).max().unwrap_or(steps);
    println!(
        "stencil {bench} interior {interior} dtype {dtype} steps {run_steps} (fused {chunk})"
    );
    let mut t = Table::new(&["mode", "wall", "GCells/s", "launches", "host bytes"]);
    let mut reference: Option<Vec<f64>> = None;
    for (policy, session) in &mut sessions {
        let policy = *policy;
        let report = session.run(run_steps)?;
        let state = session.state_f64()?;
        match &reference {
            None => reference = Some(state),
            Some(r) => {
                let max_diff =
                    r.iter().zip(&state).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
                if max_diff > 1e-4 {
                    return Err(Error::Solver(format!(
                        "{}: diverged from first mode by {max_diff}",
                        session.mode().name()
                    )));
                }
            }
        }
        let label = if policy == ExecPolicy::Auto {
            format!("auto -> {}", session.mode().name())
        } else {
            session.mode().name().to_string()
        };
        t.row(&[
            label,
            fmt::secs(report.wall_seconds),
            fmt::gcells(report.fom),
            report.invocations.to_string(),
            fmt::bytes(report.host_bytes as f64),
        ]);
    }
    print!("{}", t.render());
    if sessions.len() > 1 {
        println!("all modes agree numerically ✓");
    }
    Ok(())
}

fn run_cg(args: ParsedArgs) -> Result<()> {
    let n = args.get_usize("n", 1024)?;
    let iters = args.get_usize("iters", 64)?;
    let policies = policies(
        &args.get("mode", "all"),
        &[ExecMode::HostLoop, ExecMode::Persistent],
    )?;

    let rt = Rc::new(Runtime::new(Runtime::default_dir())?);
    let mut sessions = Vec::new();
    for policy in policies {
        let session = SessionBuilder::cg(n)
            .backend(Backend::pjrt(rt.clone()))
            .policy(policy)
            .seed(7)
            .build()?;
        sessions.push(session);
    }
    // one iteration count, aligned to the deepest fused chunk, for all modes
    let chunk = sessions.iter().map(|s| s.fused_chunk()).max().unwrap_or(1);
    let run_iters = sessions.iter().map(|s| s.aligned_steps(iters)).max().unwrap_or(iters);
    println!("cg n={n} iters={run_iters} (fused {chunk})");
    let mut t =
        Table::new(&["mode", "wall", "iters/s", "launches", "rr_final", "true ||b-Ax||^2"]);
    for session in &mut sessions {
        let rep = session.run(run_iters)?;
        let resid = session.true_residual()?.unwrap_or(f64::NAN);
        t.row(&[
            session.mode().name().to_string(),
            fmt::secs(rep.wall_seconds),
            format!("{:.0}", rep.fom),
            rep.invocations.to_string(),
            format!("{:.3e}", rep.residual.unwrap_or(f64::NAN)),
            format!("{resid:.3e}"),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cpu_perks(args: ParsedArgs) -> Result<()> {
    let bench = args.get("bench", "2d5pt");
    let size = args.get_usize("size", 512)?;
    let steps = args.get_usize("steps", 64)?;
    let threads = args.get_usize("threads", 8)?;
    let policies = policies(
        &args.get("mode", "all"),
        &[ExecMode::HostLoop, ExecMode::Persistent],
    )?;
    let spec = stencil::spec(&bench).ok_or_else(|| Error::invalid("unknown bench"))?;
    let interior = if spec.dims == 2 {
        format!("{size}x{size}")
    } else {
        format!("{size}x{size}x{size}")
    };
    // resolve --threads 0 (auto) ONCE so every mode runs with the same
    // thread count and the speedup column compares execution models only
    let threads = if threads == 0 {
        let dims: Vec<usize> =
            if spec.dims == 2 { vec![size, size] } else { vec![size, size, size] };
        let mut dom = stencil::Domain::for_spec(&spec, &dims)?;
        dom.randomize(1);
        let max = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
        let choice = perks::coordinator::autotune::tune_threads(&spec, &dom, 2, max)?;
        println!("thread autotune picked {}", choice.threads);
        choice.threads
    } else {
        threads
    };

    println!(
        "cpu persistent-threads demo: {bench} {size}^{} steps={steps} threads={threads}",
        spec.dims
    );
    let mut t = Table::new(&["mode", "wall", "GCells/s", "global traffic", "barrier wait"]);
    let mut states: Vec<Vec<f64>> = Vec::new();
    let mut walls: Vec<f64> = Vec::new();
    for policy in policies {
        let mut session = SessionBuilder::stencil(&bench, &interior, "f64")
            .backend(Backend::cpu(threads))
            .policy(policy)
            .seed(1)
            .build()?;
        let rep = session.run(steps)?;
        states.push(session.state_f64()?);
        walls.push(rep.wall_seconds);
        t.row(&[
            session.mode().name().to_string(),
            fmt::secs(rep.wall_seconds),
            fmt::gcells(rep.fom),
            fmt::bytes(rep.host_bytes as f64),
            rep.barrier_wait_seconds.map(fmt::secs).unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", t.render());
    if let ([a, b], [wa, wb]) = (states.as_slice(), walls.as_slice()) {
        let diff = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        println!("speedup: {:.2}x   max diff: {diff:.2e}", wa / wb);
    }
    Ok(())
}

fn advise(args: ParsedArgs) -> Result<()> {
    use perks::coordinator::profile;
    let dev_name = args.get("device", "A100");
    let dev = device::by_name(&dev_name)
        .ok_or_else(|| Error::invalid(format!("unknown device {dev_name:?}")))?;
    let solver = args.get("solver", "cg");
    let profile = match solver.as_str() {
        "cg" => {
            let n = args.get_usize("n", 150_000)?;
            let nnz = args.get_usize("nnz", 1_000_000)?;
            profile::profile_cg(n, nnz, 4, 10)
        }
        "stencil" => {
            let interior = args.get_usize("cells", 3072 * 3072)? as u64 * 4;
            profile::profile_stencil(interior, interior / 24, 10)
        }
        other => return Err(Error::invalid(format!("unknown solver {other:?}"))),
    };
    // capacity at minimum occupancy for a lean kernel
    let kr = perks::simgpu::KernelResources {
        threads_per_tb: 256,
        regs_per_thread: 40,
        smem_per_tb: 2048,
    };
    let occ = perks::simgpu::occupancy(&dev, &kr, 1)
        .ok_or_else(|| Error::invalid("kernel does not fit"))?;
    print!(
        "{}",
        profile.report(
            occ.free_smem_bytes_device(&dev) as f64,
            occ.free_reg_bytes_device(&dev) as f64 * 0.73
        )
    );
    Ok(())
}

fn tune(args: ParsedArgs) -> Result<()> {
    use perks::coordinator::autotune;
    let bench = args.get("bench", "2d5pt");
    let size = args.get_usize("size", 256)?;
    let spec = stencil::spec(&bench).ok_or_else(|| Error::invalid("unknown bench"))?;
    let interior: Vec<usize> =
        if spec.dims == 2 { vec![size, size] } else { vec![size, size, size] };
    let mut dom = stencil::Domain::for_spec(&spec, &interior)?;
    dom.randomize(1);
    let max = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let choice = autotune::tune_threads(&spec, &dom, 8, max)?;
    println!("measured thread sweep ({bench}, {size}^{}):", spec.dims);
    for (t, s) in &choice.sweep {
        let marker = if *t == choice.threads { "  <- best" } else { "" };
        println!("  {t:>3} threads: {}{marker}", fmt::secs(*s));
    }
    Ok(())
}

fn simulate(args: ParsedArgs) -> Result<()> {
    // `perks simulate fig5` (positional) or `--figure fig5`
    let what = match args.positional(0) {
        Some(p) => p.to_string(),
        None => args.get("figure", ""),
    };
    let dev_name = args.get("device", "A100");
    let dev = device::by_name(&dev_name)
        .ok_or_else(|| Error::invalid(format!("unknown device {dev_name:?}")))?;
    let elem = if args.get("dtype", "f64") == "f32" { 4 } else { 8 };
    let devs = [device::a100(), device::v100()];
    match what.as_str() {
        "fig5" => print!("{}", harness::render_stencil_speedups(&devs, elem, false)),
        "fig6" => print!("{}", harness::render_stencil_speedups(&devs, elem, true)),
        "fig7" => print!("{}", harness::render_fig7(&dev, elem)),
        "fig8" => print!("{}", harness::render_fig8(&dev, elem)),
        "fig9" => print!("{}", harness::render_fig9(&dev, elem)),
        other => {
            return Err(Error::invalid(format!(
                "unknown simulation {other:?}; fig1/fig2/table2/table4 live in `cargo bench`"
            )))
        }
    }
    Ok(())
}
