//! GPU device catalog (Table I of the paper) plus the microarchitectural
//! constants the concurrency model needs (clocks and latencies from the
//! microbenchmarking literature the paper cites: Jia et al. for V100/T4,
//! the A100 whitepaper, Mei & Chu for the memory hierarchy).

/// Static description of one GPU.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub smxs: usize,
    /// Register file capacity, total bytes (256 KiB / SMX on all three).
    pub regfile_bytes: usize,
    /// Shared-memory capacity usable as scratchpad, total bytes.
    pub smem_bytes: usize,
    pub l2_bytes: usize,
    /// Device (global) memory bandwidth, bytes/s.
    pub gmem_bw: f64,
    /// SM clock, Hz.
    pub clock_hz: f64,
    /// Global-memory load latency, cycles.
    pub gm_latency: f64,
    /// L2 hit latency, cycles.
    pub l2_latency: f64,
    /// Shared-memory latency, cycles.
    pub sm_latency: f64,
    /// Shared-memory bandwidth per SMX, bytes/cycle (32 banks x 4 B).
    pub smem_bytes_per_cycle: f64,
    /// Max resident threads per SMX.
    pub max_threads_per_smx: usize,
    /// Max thread blocks per SMX.
    pub max_tb_per_smx: usize,
}

impl DeviceSpec {
    /// Register file bytes per SMX.
    pub fn regfile_per_smx(&self) -> usize {
        self.regfile_bytes / self.smxs
    }

    /// Shared memory bytes per SMX.
    pub fn smem_per_smx(&self) -> usize {
        self.smem_bytes / self.smxs
    }

    /// Aggregate shared-memory bandwidth, bytes/s.
    pub fn smem_bw(&self) -> f64 {
        self.smem_bytes_per_cycle * self.clock_hz * self.smxs as f64
    }

    /// Total on-chip capacity (RF + smem), bytes — the PERKS cache budget
    /// upper bound (Fig 1's right axis).
    pub fn onchip_bytes(&self) -> usize {
        self.regfile_bytes + self.smem_bytes
    }
}

/// Tesla P100 (Pascal) — Table I column 1.
pub fn p100() -> DeviceSpec {
    DeviceSpec {
        name: "P100",
        smxs: 56,
        regfile_bytes: 14 * 1024 * 1024,
        smem_bytes: 3_670_016, // 3.5 MiB
        l2_bytes: 4 * 1024 * 1024,
        gmem_bw: 720e9,
        clock_hz: 1.33e9,
        gm_latency: 570.0,
        l2_latency: 260.0,
        sm_latency: 24.0,
        smem_bytes_per_cycle: 128.0,
        max_threads_per_smx: 2048,
        max_tb_per_smx: 32,
    }
}

/// Tesla V100 (Volta) — Table I column 2.
pub fn v100() -> DeviceSpec {
    DeviceSpec {
        name: "V100",
        smxs: 80,
        regfile_bytes: 20 * 1024 * 1024,
        smem_bytes: 7_864_320, // 7.5 MiB (96 KiB/SMX)
        l2_bytes: 6 * 1024 * 1024,
        gmem_bw: 900e9,
        clock_hz: 1.53e9,
        gm_latency: 440.0,
        l2_latency: 220.0,
        sm_latency: 19.0,
        smem_bytes_per_cycle: 128.0,
        max_threads_per_smx: 2048,
        max_tb_per_smx: 32,
    }
}

/// A100 (Ampere) — Table I column 3.
pub fn a100() -> DeviceSpec {
    DeviceSpec {
        name: "A100",
        smxs: 108,
        regfile_bytes: 27 * 1024 * 1024,
        smem_bytes: 18_130_862, // 17.29 MiB (164 KiB/SMX usable)
        l2_bytes: 40 * 1024 * 1024,
        gmem_bw: 1555e9,
        clock_hz: 1.41e9,
        gm_latency: 470.0,
        l2_latency: 200.0,
        sm_latency: 19.0,
        smem_bytes_per_cycle: 128.0,
        max_threads_per_smx: 2048,
        max_tb_per_smx: 32,
    }
}

/// Look up by case-insensitive name.
pub fn by_name(name: &str) -> Option<DeviceSpec> {
    match name.to_ascii_uppercase().as_str() {
        "P100" => Some(p100()),
        "V100" => Some(v100()),
        "A100" => Some(a100()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_features() {
        // assert the catalog matches Table I of the paper
        let p = p100();
        assert_eq!(p.smxs, 56);
        assert_eq!(p.regfile_bytes, 14 * 1024 * 1024);
        assert_eq!(p.gmem_bw, 720e9);

        let v = v100();
        assert_eq!(v.smxs, 80);
        assert_eq!(v.regfile_bytes, 20 * 1024 * 1024);
        assert_eq!(v.l2_bytes, 6 * 1024 * 1024);
        assert_eq!(v.gmem_bw, 900e9);

        let a = a100();
        assert_eq!(a.smxs, 108);
        assert_eq!(a.regfile_bytes, 27 * 1024 * 1024);
        assert_eq!(a.l2_bytes, 40 * 1024 * 1024);
        assert_eq!(a.gmem_bw, 1555e9);
        // 17.29 MB shared memory
        assert!((a.smem_bytes as f64 / 1024.0 / 1024.0 - 17.29).abs() < 0.01);
    }

    #[test]
    fn per_smx_resources_are_256k_regs() {
        for d in [p100(), v100(), a100()] {
            assert_eq!(d.regfile_per_smx(), 256 * 1024, "{}", d.name);
        }
        assert_eq!(v100().smem_per_smx(), 96 * 1024);
    }

    #[test]
    fn smem_bw_exceeds_gmem_bw() {
        // the premise of Eq 8: caching moves the bottleneck to a much
        // faster level
        for d in [p100(), v100(), a100()] {
            assert!(d.smem_bw() > 5.0 * d.gmem_bw, "{}", d.name);
        }
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("a100").unwrap().name, "A100");
        assert!(by_name("H100").is_none());
    }
}
