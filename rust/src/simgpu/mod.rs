//! GPU memory-hierarchy simulator substrate.
//!
//! The paper's testbed (V100/A100 silicon) is not available here, so this
//! module instantiates the paper's own analytical machinery with the
//! published device parameters (Table I) and latency-literature constants:
//!
//! * `device` — Table I catalog;
//! * `occupancy` — resource accounting (Fig 1) + Table IV saturation;
//! * `concurrency` — Little's-law C_hw, efficiency function (Eq 12-13);
//! * `perfmodel` — the roofline-style projection (Eqs 5-11);
//! * `opt` — the Fig 2 optimization-level lineup.

pub mod concurrency;
pub mod device;
pub mod occupancy;
pub mod opt;
pub mod perfmodel;

pub use device::{a100, by_name, p100, v100, DeviceSpec};
pub use occupancy::{occupancy, KernelResources, Occupancy};
pub use perfmodel::{CacheSplit, StencilScenario, TileGeom};
