//! Concurrency model (paper §IV-C/D): software-exposed concurrency vs the
//! hardware concurrency required by Little's law, and the efficiency
//! function E(C_sw, C_hw) of Eq 12, including the §IV-D L2-hit correction.

use crate::simgpu::device::DeviceSpec;

/// Data-access operation classes the paper models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    GlobalMem,
    L2,
    SharedMem,
}

/// Hardware concurrency C_hw(op) = THR(op) x L(op) (Eq 13), expressed in
/// bytes in flight per SMX.
pub fn c_hw_bytes(dev: &DeviceSpec, op: Op) -> f64 {
    match op {
        Op::GlobalMem => {
            let bytes_per_cycle = dev.gmem_bw / dev.smxs as f64 / dev.clock_hz;
            bytes_per_cycle * dev.gm_latency
        }
        Op::L2 => {
            // L2 bandwidth ~ 3x global on these parts; latency lower
            let bytes_per_cycle = 3.0 * dev.gmem_bw / dev.smxs as f64 / dev.clock_hz;
            bytes_per_cycle * dev.l2_latency
        }
        Op::SharedMem => dev.smem_bytes_per_cycle * dev.sm_latency,
    }
}

/// Software concurrency per SMX: independent in-flight bytes exposed by
/// one thread block times TB/SMX.
#[derive(Clone, Copy, Debug)]
pub struct SwConcurrency {
    /// Independent outstanding access bytes per thread (ILP x access size).
    pub bytes_per_thread: f64,
    pub threads_per_tb: usize,
    pub tb_per_smx: usize,
}

impl SwConcurrency {
    pub fn per_smx(&self) -> f64 {
        self.bytes_per_thread * self.threads_per_tb as f64 * self.tb_per_smx as f64
    }
}

/// Efficiency function (Eq 12): 1 when the software saturates the
/// hardware, proportional shortfall otherwise.
pub fn efficiency(c_sw: f64, c_hw: f64) -> f64 {
    if c_hw <= 0.0 {
        return 1.0;
    }
    (c_sw / c_hw).min(1.0)
}

/// §IV-D: when a fraction `l2_hit_rate` of the traffic hits in L2, the
/// concurrency needed grows (L2 completes accesses faster than the GM
/// pipeline, so more must be in flight to keep the same bandwidth).
/// Blended requirement: (1-h) * C_hw(GM) + h * C_hw(L2-equivalent demand).
pub fn c_hw_blended(dev: &DeviceSpec, l2_hit_rate: f64) -> f64 {
    let gm = c_hw_bytes(dev, Op::GlobalMem);
    let l2 = c_hw_bytes(dev, Op::L2);
    (1.0 - l2_hit_rate) * gm + l2_hit_rate * l2
}

/// One row of the Table II analysis.
#[derive(Clone, Debug)]
pub struct ConcurrencyRow {
    pub tb_per_smx: usize,
    pub used_reg_bytes: usize,
    pub unused_reg_bytes: usize,
    pub gm_load_ops: usize,
    pub gm_store_ops: usize,
    pub efficiency: f64,
    pub projected_gcells: f64,
}

/// Reproduce the Table II sweep for a kernel described by per-TB op counts
/// (the paper's static analysis output: 2580 loads + 2048 stores per TB
/// for the sp 2d5pt kernel on a 3072^2 domain) and a peak rate at full
/// saturation.
pub fn table_ii(
    dev: &DeviceSpec,
    regs_per_thread: usize,
    threads_per_tb: usize,
    loads_per_tb: usize,
    stores_per_tb: usize,
    peak_gcells: f64,
    l2_hit_rate: f64,
    tb_values: &[usize],
) -> Vec<ConcurrencyRow> {
    let c_hw = c_hw_blended(dev, l2_hit_rate);
    tb_values
        .iter()
        .map(|&tb| {
            let used = threads_per_tb * regs_per_thread * 4 * tb;
            let c_sw = ((loads_per_tb + stores_per_tb) * tb) as f64 * 4.0 / 5.0;
            // ops are counted per TB over the whole step; the in-flight
            // window is ~1/5 of them (unrolled stream, IPT=8..10, two
            // concurrent load streams) — calibrated so TB/SMX=1 lands at
            // the paper's 68.5% of saturated
            let e = efficiency(c_sw, c_hw);
            ConcurrencyRow {
                tb_per_smx: tb,
                used_reg_bytes: used,
                unused_reg_bytes: dev.regfile_per_smx().saturating_sub(used),
                gm_load_ops: loads_per_tb * tb,
                gm_store_ops: stores_per_tb * tb,
                efficiency: e,
                projected_gcells: peak_gcells * e,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::a100;

    #[test]
    fn little_law_magnitudes() {
        let dev = a100();
        let gm = c_hw_bytes(&dev, Op::GlobalMem);
        // ~10 bytes/cycle/SMX x ~470 cycles => a few KB in flight per SMX
        assert!((1_000.0..20_000.0).contains(&gm), "gm C_hw = {gm}");
        let sm = c_hw_bytes(&dev, Op::SharedMem);
        assert!(sm < gm, "smem needs less in-flight than gm");
    }

    #[test]
    fn efficiency_saturates_at_one() {
        assert_eq!(efficiency(10.0, 5.0), 1.0);
        assert_eq!(efficiency(2.5, 5.0), 0.5);
        assert_eq!(efficiency(0.0, 5.0), 0.0);
    }

    #[test]
    fn l2_hits_raise_required_concurrency() {
        let dev = a100();
        assert!(c_hw_blended(&dev, 0.8) > c_hw_blended(&dev, 0.0));
    }

    #[test]
    fn table_ii_shape() {
        // paper: TB/SMX 1 -> 94.75, 2 -> 133.24, 8 -> 138.29 GCells/s;
        // i.e. 1 TB is ~68% of peak, 2 TB is ~96%, 8 TB saturated.
        let dev = a100();
        let rows = table_ii(&dev, 32, 256, 2580, 2048, 138.29, 0.6, &[1, 2, 8]);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].used_reg_bytes, 32 * 1024);
        assert_eq!(rows[2].unused_reg_bytes, 0);
        // calibration check: TB/SMX=1 lands near the paper's 68.5%
        assert!(
            (rows[0].efficiency - 0.685).abs() < 0.1,
            "TB=1 efficiency {} should be ~0.685",
            rows[0].efficiency
        );
        // monotone non-decreasing performance with occupancy
        assert!(rows[0].projected_gcells <= rows[1].projected_gcells);
        assert!(rows[1].projected_gcells <= rows[2].projected_gcells);
        // TB=1 must show a visible gap; TB=8 saturated
        assert!(rows[0].efficiency < 1.0);
        assert!((rows[2].efficiency - 1.0).abs() < 1e-9);
        // the op counts are the static-analysis numbers scaled by TB
        assert_eq!(rows[1].gm_load_ops, 5160);
        assert_eq!(rows[1].gm_store_ops, 4096);
    }
}
