//! Optimization-level catalog for the Fig 2 motivation experiment.
//!
//! Fig 2 decomposes the per-step runtime of a 2d9pt dp stencil into the
//! inter-step data movement (constant across implementations) and the
//! compute part (shrinking as the implementation gets more optimized), and
//! shows that the more optimized the kernel, the larger the speedup that
//! caching (PERKS) yields. The catalog models each published baseline by
//! its compute-time ratio relative to the memory time, and its traffic
//! factor (temporal-blocking schemes AN5D/StencilGen already avoid part of
//! the inter-step traffic).

use crate::simgpu::device::DeviceSpec;
use crate::simgpu::perfmodel::StencilScenario;

/// One implementation of the Fig 2 lineup.
#[derive(Clone, Copy, Debug)]
pub struct OptLevel {
    pub name: &'static str,
    /// Compute time as a fraction of the (uncached) memory time.
    pub compute_ratio: f64,
    /// Fraction of the inter-step traffic this implementation still pays
    /// (1.0 for everything but temporal blocking).
    pub traffic_factor: f64,
}

/// The Fig 2 lineup, least to most optimized.
pub fn catalog() -> Vec<OptLevel> {
    vec![
        OptLevel { name: "NAIVE", compute_ratio: 2.00, traffic_factor: 1.0 },
        OptLevel { name: "NVCC-OPT", compute_ratio: 1.20, traffic_factor: 1.0 },
        OptLevel { name: "SM-OPT", compute_ratio: 0.45, traffic_factor: 1.0 },
        OptLevel { name: "SSAM", compute_ratio: 0.30, traffic_factor: 1.0 },
        OptLevel { name: "AN5D", compute_ratio: 0.10, traffic_factor: 0.60 },
        OptLevel { name: "STENCILGEN", compute_ratio: 0.08, traffic_factor: 0.55 },
    ]
}

/// Per-run decomposition for Fig 2.
#[derive(Clone, Copy, Debug)]
pub struct Fig2Row {
    pub level: OptLevel,
    pub traffic_seconds: f64,
    pub compute_seconds: f64,
    /// Speedup if 50% of the inter-step traffic were cached (the dashed
    /// projection line of Fig 2).
    pub speedup_cache_half: f64,
}

impl Fig2Row {
    pub fn total_seconds(&self) -> f64 {
        self.traffic_seconds + self.compute_seconds
    }
}

/// Evaluate the lineup on a scenario (the paper: 2d9pt dp 3072^2, 20
/// steps, A100).
pub fn fig2(dev: &DeviceSpec, s: &StencilScenario) -> Vec<Fig2Row> {
    let mem_time_full = 2.0 * s.steps as f64 * s.domain_bytes() / dev.gmem_bw;
    catalog()
        .into_iter()
        .map(|level| {
            let traffic = mem_time_full * level.traffic_factor;
            let compute = mem_time_full * level.compute_ratio;
            // caching half the domain halves the *remaining* traffic
            let cached = traffic * 0.5 + compute;
            Fig2Row {
                level,
                traffic_seconds: traffic,
                compute_seconds: compute,
                speedup_cache_half: (traffic + compute) / cached,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::a100;

    fn scenario() -> StencilScenario {
        StencilScenario {
            cells: 3072.0 * 3072.0,
            elem: 8,
            radius: 1,
            steps: 20,
            kernel_smem_per_cell: 2.0,
        }
    }

    #[test]
    fn more_optimized_implies_more_caching_speedup() {
        // the core claim of Fig 2 (and §III-A "Impact on Optimized
        // Kernels"): speedup-if-cached grows monotonically with the
        // optimization level
        let rows = fig2(&a100(), &scenario());
        for w in rows.windows(2) {
            assert!(
                w[1].speedup_cache_half >= w[0].speedup_cache_half,
                "{} {} -> {} {}",
                w[0].level.name,
                w[0].speedup_cache_half,
                w[1].level.name,
                w[1].speedup_cache_half
            );
        }
    }

    #[test]
    fn runtimes_shrink_with_optimization() {
        let rows = fig2(&a100(), &scenario());
        for w in rows.windows(2) {
            assert!(w[1].total_seconds() <= w[0].total_seconds());
        }
    }

    #[test]
    fn traffic_time_constant_for_non_temporal_schemes() {
        let rows = fig2(&a100(), &scenario());
        let t0 = rows[0].traffic_seconds;
        for r in rows.iter().take(4) {
            assert_eq!(r.traffic_seconds, t0, "{}", r.level.name);
        }
        // temporal blocking reduces it
        assert!(rows[4].traffic_seconds < t0);
    }

    #[test]
    fn magnitudes_match_fig2_axis() {
        // Fig 2's bars are ~2-6 ms for 20 steps; memory time alone:
        // 2*20*75.5MB / 1555 GB/s = 1.94 ms
        let rows = fig2(&a100(), &scenario());
        let mem = rows[0].traffic_seconds;
        assert!((mem * 1e3 - 1.94).abs() < 0.1, "mem time {} ms", mem * 1e3);
        assert!(rows[0].total_seconds() * 1e3 < 10.0);
    }
}
