//! Occupancy and resource accounting (Fig 1, Table IV).
//!
//! Reducing TB/SMX frees registers and shared memory for PERKS caching;
//! this module computes the freed capacity for a kernel's resource usage,
//! and the minimum domain size that saturates the device (the paper's
//! Table IV criterion for a fair comparison).

use crate::simgpu::device::DeviceSpec;

/// Resource usage of one kernel configuration.
#[derive(Clone, Copy, Debug)]
pub struct KernelResources {
    pub threads_per_tb: usize,
    pub regs_per_thread: usize,
    /// Shared memory per thread block, bytes.
    pub smem_per_tb: usize,
}

impl KernelResources {
    /// Typical optimized stencil kernel (the SM-OPT baseline): 256
    /// threads, 32 regs/thread, smem plane buffering of `plane_bytes`.
    pub fn stencil_baseline(plane_bytes: usize) -> Self {
        Self { threads_per_tb: 256, regs_per_thread: 32, smem_per_tb: plane_bytes }
    }
}

/// Resolved occupancy at a given TB/SMX.
#[derive(Clone, Copy, Debug)]
pub struct Occupancy {
    pub tb_per_smx: usize,
    pub threads_per_smx: usize,
    pub used_reg_bytes_per_smx: usize,
    pub used_smem_bytes_per_smx: usize,
    pub free_reg_bytes_per_smx: usize,
    pub free_smem_bytes_per_smx: usize,
}

impl Occupancy {
    /// Unused on-chip bytes across the whole device (Fig 1 right axis).
    pub fn free_bytes_device(&self, dev: &DeviceSpec) -> usize {
        (self.free_reg_bytes_per_smx + self.free_smem_bytes_per_smx) * dev.smxs
    }

    /// Freed register bytes device-wide.
    pub fn free_reg_bytes_device(&self, dev: &DeviceSpec) -> usize {
        self.free_reg_bytes_per_smx * dev.smxs
    }

    /// Freed shared-memory bytes device-wide.
    pub fn free_smem_bytes_device(&self, dev: &DeviceSpec) -> usize {
        self.free_smem_bytes_per_smx * dev.smxs
    }
}

/// Compute occupancy of `kr` at `tb_per_smx` blocks per SMX; `None` if the
/// configuration does not fit (registers, smem or thread slots exhausted).
pub fn occupancy(dev: &DeviceSpec, kr: &KernelResources, tb_per_smx: usize) -> Option<Occupancy> {
    let threads = kr.threads_per_tb * tb_per_smx;
    if threads > dev.max_threads_per_smx || tb_per_smx > dev.max_tb_per_smx {
        return None;
    }
    let used_regs = threads * kr.regs_per_thread * 4;
    let used_smem = kr.smem_per_tb * tb_per_smx;
    if used_regs > dev.regfile_per_smx() || used_smem > dev.smem_per_smx() {
        return None;
    }
    Some(Occupancy {
        tb_per_smx,
        threads_per_smx: threads,
        used_reg_bytes_per_smx: used_regs,
        used_smem_bytes_per_smx: used_smem,
        free_reg_bytes_per_smx: dev.regfile_per_smx() - used_regs,
        free_smem_bytes_per_smx: dev.smem_per_smx() - used_smem,
    })
}

/// The maximum TB/SMX the kernel supports on this device.
pub fn max_tb_per_smx(dev: &DeviceSpec, kr: &KernelResources) -> usize {
    (1..=dev.max_tb_per_smx).take_while(|&t| occupancy(dev, kr, t).is_some()).count()
}

/// Calibrated saturation factor: Little's law gives the *minimum* bytes
/// in flight per SMX, but a real kernel only keeps ~1% of its resident
/// accesses in flight at once (2048 threads x ~10 accesses each, of which
/// one generation overlaps), and §IV-D showed L2-heavy traffic needs ~2x
/// more. Calibrated once against the paper's Table IV (A100 sp 2d:
/// 4608x3072 => ~131k cells/SMX); applied uniformly to all devices.
pub const SATURATION_FACTOR: f64 = 100.0;

/// Minimum cells per SMX needed to saturate the memory pipeline:
/// Little's law on global-memory accesses scaled by the calibrated
/// saturation factor.
pub fn saturating_cells_per_smx(dev: &DeviceSpec, elem: usize, factor: f64) -> usize {
    let bw_per_smx = dev.gmem_bw / dev.smxs as f64; // bytes/s
    let bytes_per_cycle = bw_per_smx / dev.clock_hz;
    let c_hw = bytes_per_cycle * dev.gm_latency; // bytes in flight (Little)
    (c_hw / elem as f64 * factor) as usize
}

/// Table IV model: the minimum 2D domain (x, y) saturating the device for
/// a stencil of `radius`, snapped up to multiples of 256 (x) and 128 (y),
/// honouring the paper's convention of x >= y.
pub fn min_domain_2d(dev: &DeviceSpec, elem: usize, _radius: usize) -> (usize, usize) {
    let per_smx = saturating_cells_per_smx(dev, elem, SATURATION_FACTOR);
    let total = per_smx * dev.smxs;
    // pick x:y aspect near 4:3, snap x to 256, y to 128
    let mut y = ((total as f64 * 3.0 / 4.0).sqrt() * (1.0 / 1.1547)) as usize;
    y = (y / 128).max(1) * 128;
    let mut x = total / y.max(1);
    x = x.div_ceil(256).max(1) * 256;
    (x, y)
}

/// Table IV model for 3D domains: (x, y, z) snapped to multiples of 32.
pub fn min_domain_3d(dev: &DeviceSpec, elem: usize, _radius: usize) -> (usize, usize, usize) {
    let per_smx = saturating_cells_per_smx(dev, elem, SATURATION_FACTOR);
    let total = (per_smx * dev.smxs) as f64;
    let side = total.cbrt();
    let snap = |v: f64| ((v / 32.0).ceil() as usize).max(1) * 32;
    (snap(side), snap(side), snap(side))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::{a100, v100};

    #[test]
    fn fig1_shape_lower_occupancy_frees_resources() {
        // Fig 1: TB/SMX from 8 down to 1 monotonically frees resources;
        // at peak occupancy more than 11.2 MB is still unused on A100 for
        // the 2d9pt dp kernel.
        let dev = a100();
        let kr = KernelResources { threads_per_tb: 256, regs_per_thread: 25, smem_per_tb: 10 * 1024 };
        let mut prev_free = 0usize;
        for tb in (1..=8).rev() {
            let occ = occupancy(&dev, &kr, tb).unwrap();
            let free = occ.free_bytes_device(&dev);
            // TB/SMX decreasing => freed resources monotonically grow
            assert!(free >= prev_free, "tb={tb}: {free} < {prev_free}");
            prev_free = free;
        }
        let at_peak = occupancy(&dev, &kr, 8).unwrap().free_bytes_device(&dev);
        assert!(at_peak as f64 > 11.2e6, "unused at peak = {at_peak}");
    }

    #[test]
    fn occupancy_rejects_oversubscription() {
        let dev = a100();
        let kr = KernelResources { threads_per_tb: 1024, regs_per_thread: 64, smem_per_tb: 0 };
        // 1024 threads x 64 regs x 4 = 256 KiB = whole RF: only 1 TB fits
        assert!(occupancy(&dev, &kr, 1).is_some());
        assert!(occupancy(&dev, &kr, 2).is_none());
        assert_eq!(max_tb_per_smx(&dev, &kr), 1);
    }

    #[test]
    fn table_ii_register_accounting() {
        // Table II: 2d5pt sp kernel at TB/SMX=1 uses 32KB regs, leaving
        // 224KB; at 8 it uses 256KB leaving 0.
        let dev = a100();
        let kr = KernelResources { threads_per_tb: 256, regs_per_thread: 32, smem_per_tb: 0 };
        let o1 = occupancy(&dev, &kr, 1).unwrap();
        assert_eq!(o1.used_reg_bytes_per_smx, 32 * 1024);
        assert_eq!(o1.free_reg_bytes_per_smx, 224 * 1024);
        let o8 = occupancy(&dev, &kr, 8).unwrap();
        assert_eq!(o8.used_reg_bytes_per_smx, 256 * 1024);
        assert_eq!(o8.free_reg_bytes_per_smx, 0);
    }

    #[test]
    fn min_domains_scale_with_device_and_precision() {
        let a = a100();
        let v = v100();
        // A100 needs larger domains than V100 (more SMXs, more BW)
        let (ax, ay) = min_domain_2d(&a, 4, 1);
        let (vx, vy) = min_domain_2d(&v, 4, 1);
        assert!(ax * ay >= vx * vy, "A100 {ax}x{ay} vs V100 {vx}x{vy}");
        // single precision needs more cells than double (same bytes)
        let (dx, dy) = min_domain_2d(&a, 8, 1);
        assert!(ax * ay >= dx * dy);
        // sanity: paper's Table IV magnitudes (A100 sp 2d: 4608x3072)
        let cells = (ax * ay) as f64;
        assert!(
            (1e6..1e8).contains(&cells),
            "A100 sp min domain {ax}x{ay} out of plausible range"
        );
    }

    #[test]
    fn min_domain_3d_plausible() {
        let (x, y, z) = min_domain_3d(&a100(), 4, 1);
        assert!(x % 32 == 0 && y % 32 == 0 && z % 32 == 0);
        let cells = (x * y * z) as f64;
        assert!((1e6..1e9).contains(&cells));
    }
}
