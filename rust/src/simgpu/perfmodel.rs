//! The paper's roofline-style performance model (§IV, Eqs 5-11).
//!
//! Projects the best-case runtime of a PERKS kernel from the global-memory
//! traffic after caching, the unavoidable halo traffic, and the shared-
//! memory traffic of the cached portion; then applies the efficiency
//! function to get expected measured performance. All byte accounting is
//! explicit so unit tests can pin the worked examples of §IV-B.

use crate::simgpu::device::DeviceSpec;

/// One stencil experiment instance.
#[derive(Clone, Copy, Debug)]
pub struct StencilScenario {
    /// Total domain cells (D / S(type)).
    pub cells: f64,
    /// Element size S(type) in bytes (4 = sp, 8 = dp).
    pub elem: usize,
    pub radius: usize,
    /// Time steps N.
    pub steps: usize,
    /// Shared memory bytes the *kernel itself* moves per cell per step
    /// (A_sm(KERNEL)/D/N): the SM-OPT baseline stages each input cell
    /// through shared memory once => 1 load + 1 store.
    pub kernel_smem_per_cell: f64,
}

impl StencilScenario {
    pub fn domain_bytes(&self) -> f64 {
        self.cells * self.elem as f64
    }
}

/// How the cached bytes split between shared memory and registers
/// (D_cache = D_cache_sm + D_cache_reg).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheSplit {
    pub sm_bytes: f64,
    pub reg_bytes: f64,
}

impl CacheSplit {
    pub fn total(&self) -> f64 {
        self.sm_bytes + self.reg_bytes
    }
}

/// Thread-block tile geometry used for the halo-traffic estimate (Eq 9).
#[derive(Clone, Copy, Debug)]
pub struct TileGeom {
    pub cells_per_tb: f64,
    /// Perimeter cells of one tile (2(tx+ty) in 2D; surface in 3D).
    pub perimeter_cells: f64,
}

impl TileGeom {
    pub fn tile_2d(tx: usize, ty: usize) -> Self {
        Self { cells_per_tb: (tx * ty) as f64, perimeter_cells: (2 * (tx + ty)) as f64 }
    }

    pub fn tile_3d(t: usize) -> Self {
        Self { cells_per_tb: (t * t * t) as f64, perimeter_cells: (6 * t * t) as f64 }
    }
}

/// Eq 5: total global-memory bytes over N steps given cached bytes.
pub fn a_gm(s: &StencilScenario, cached_bytes: f64) -> f64 {
    let d = s.domain_bytes();
    let cached = cached_bytes.min(d);
    let uncached = d - cached;
    2.0 * s.steps as f64 * uncached + 2.0 * cached
}

/// Eq 6: time for global-memory traffic.
pub fn t_gm(dev: &DeviceSpec, s: &StencilScenario, cached_bytes: f64) -> f64 {
    a_gm(s, cached_bytes) / dev.gmem_bw
}

/// Eq 9: halo traffic of the cached region — boundary threads of cached
/// TBs still load+store `radius` rings to global memory each step.
pub fn a_gm_halo(s: &StencilScenario, cached_bytes: f64, tile: &TileGeom) -> f64 {
    let cached_cells = (cached_bytes / s.elem as f64).min(s.cells);
    let n_tbs = (cached_cells / tile.cells_per_tb).ceil();
    let halo_cells_per_tb = tile.perimeter_cells * s.radius as f64;
    2.0 * s.steps as f64 * n_tbs * halo_cells_per_tb * s.elem as f64
}

pub fn t_gm_halo(dev: &DeviceSpec, s: &StencilScenario, cached: f64, tile: &TileGeom) -> f64 {
    a_gm_halo(s, cached, tile) / dev.gmem_bw
}

/// Eq 7: shared-memory bytes of the cached-in-smem portion across steps.
pub fn a_sm_cache(s: &StencilScenario, sm_cached_bytes: f64) -> f64 {
    2.0 * (s.steps.saturating_sub(1)) as f64 * sm_cached_bytes
}

/// A_sm(KERNEL): smem traffic the baseline kernel already does.
pub fn a_sm_kernel(s: &StencilScenario) -> f64 {
    s.kernel_smem_per_cell * s.cells * s.steps as f64 * s.elem as f64
}

/// Eq 8: shared-memory time.
pub fn t_sm(dev: &DeviceSpec, s: &StencilScenario, split: &CacheSplit) -> f64 {
    (a_sm_cache(s, split.sm_bytes) + a_sm_kernel(s)) / dev.smem_bw()
}

/// Eq 10: projected best-case PERKS runtime.
pub fn t_perks(dev: &DeviceSpec, s: &StencilScenario, split: &CacheSplit, tile: &TileGeom) -> f64 {
    let gm = t_gm(dev, s, split.total()) + t_gm_halo(dev, s, split.total(), tile);
    gm.max(t_sm(dev, s, split))
}

/// Eq 11: projected peak performance in cells/s.
pub fn projected_peak(
    dev: &DeviceSpec,
    s: &StencilScenario,
    split: &CacheSplit,
    tile: &TileGeom,
) -> f64 {
    s.cells * s.steps as f64 / t_perks(dev, s, split, tile)
}

/// Baseline (non-PERKS) time: the whole domain round-trips every step;
/// `efficiency` is the fraction of peak bandwidth the tuned baseline
/// sustains (well-saturated stencils reach ~85%). When the domain fits in
/// L2 the baseline streams from L2 (~3x HBM) — this is why the paper's
/// small-domain speedups are *lower* on A100 (40 MB L2 catches them) than
/// on V100 (6 MB L2 does not).
pub fn t_baseline(dev: &DeviceSpec, s: &StencilScenario, efficiency: f64) -> f64 {
    // 1.5x, not the raw 3x L2 stream rate: the ping-pong output array and
    // write-allocate churn keep the relaunched baseline from exploiting
    // L2 fully (calibrated against Fig 6's A100-vs-V100 asymmetry).
    let bw = if s.domain_bytes() <= dev.l2_bytes as f64 {
        1.5 * dev.gmem_bw
    } else {
        dev.gmem_bw
    };
    2.0 * s.steps as f64 * s.domain_bytes() / bw / efficiency
}

/// Measured-performance calibration constants, from the paper's §VI-H:
/// PERKS measures 64% of projected peak on large domains, 59% on small.
pub const EFF_BASELINE: f64 = 0.85;
pub const EFF_PERKS_LARGE: f64 = 0.64;
pub const EFF_PERKS_SMALL: f64 = 0.59;

/// Expected measured speedup of PERKS over the baseline for a scenario.
/// `perks_eff` is the measured/projected calibration (§VI-H).
pub fn speedup(
    dev: &DeviceSpec,
    s: &StencilScenario,
    split: &CacheSplit,
    tile: &TileGeom,
    perks_eff: f64,
) -> f64 {
    let base = t_baseline(dev, s, EFF_BASELINE);
    let perks = t_perks(dev, s, split, tile) / perks_eff;
    base / perks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::a100;

    /// §IV-B worked example 1: sp 2d5pt, D = 3072^2, cache 3072*2448,
    /// N = 1000 => T_gm = 9900.70 us and P = 876.09 GCells/s.
    #[test]
    fn paper_worked_example_large_domain() {
        let dev = a100();
        let s = StencilScenario {
            cells: 3072.0 * 3072.0,
            elem: 4,
            radius: 1,
            steps: 1000,
            kernel_smem_per_cell: 2.0,
        };
        let cached = 3072.0 * 2448.0 * 4.0;
        let t = t_gm(&dev, &s, cached);
        assert!(
            (t * 1e6 - 9900.70).abs() < 5.0,
            "T_gm = {} us, paper says 9900.70",
            t * 1e6
        );
        // halo: paper counts 216 TBs x (136*2 + 256*2) cells x 2 x 2 / step
        // our tile model with 256x136 tiles reproduces the same magnitude
        let tile = TileGeom::tile_2d(256, 136);
        let th = t_gm_halo(&dev, &s, cached, &tile);
        assert!(
            (th * 1e6 - 871.22).abs() < 90.0,
            "T_halo = {} us, paper says 871.22",
            th * 1e6
        );
        let split = CacheSplit { sm_bytes: cached / 2.0, reg_bytes: cached / 2.0 };
        let p = projected_peak(&dev, &s, &split, &tile);
        assert!(
            (p / 1e9 - 876.09).abs() < 80.0,
            "P = {} GCells/s, paper says 876.09",
            p / 1e9
        );
        // paper measured 444.19 = 50.7% of projected; our calibrated
        // estimate should land within a factor ~1.3 of that
        let m = p * EFF_PERKS_LARGE;
        assert!((m / 1e9 - 444.19).abs() < 150.0, "measured estimate {}", m / 1e9);
    }

    /// §IV-B worked example 2: fully cached small domain D = 3072*2448,
    /// smem-bound => T_sm = 7.6 ms, P = 986.38 GCells/s.
    #[test]
    fn paper_worked_example_small_domain() {
        let dev = a100();
        let s = StencilScenario {
            cells: 3072.0 * 2448.0,
            elem: 4,
            radius: 1,
            steps: 1000,
            kernel_smem_per_cell: 4.0, // the paper's baseline: D*1000*4 bytes
        };
        let sm_cached = 3072.0 * 1152.0 * 4.0;
        let split = CacheSplit { sm_bytes: sm_cached, reg_bytes: s.domain_bytes() - sm_cached };
        let t = t_sm(&dev, &s, &split);
        assert!((t * 1e3 - 7.6).abs() < 1.5, "T_sm = {} ms, paper says 7.6", t * 1e3);
        let tile = TileGeom::tile_2d(256, 136);
        let p = projected_peak(&dev, &s, &split, &tile);
        assert!(
            (p / 1e9 - 986.38).abs() < 200.0,
            "P = {} GCells/s, paper says 986.38",
            p / 1e9
        );
    }

    #[test]
    fn eq5_identities() {
        let s = StencilScenario {
            cells: 1000.0,
            elem: 4,
            radius: 1,
            steps: 10,
            kernel_smem_per_cell: 2.0,
        };
        // no caching: 2*N*D
        assert_eq!(a_gm(&s, 0.0), 2.0 * 10.0 * 4000.0);
        // full caching: 2*D (one initial load + one final store)
        assert_eq!(a_gm(&s, 4000.0), 2.0 * 4000.0);
        // caching never increases traffic, monotone in cached bytes
        let mut prev = f64::INFINITY;
        for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let t = a_gm(&s, 4000.0 * frac);
            assert!(t <= prev);
            prev = t;
        }
    }

    #[test]
    fn speedup_increases_with_cache_and_steps() {
        let dev = a100();
        let tile = TileGeom::tile_2d(256, 128);
        let mk = |steps| StencilScenario {
            cells: 3072.0 * 3072.0,
            elem: 8,
            radius: 1,
            steps,
            kernel_smem_per_cell: 2.0,
        };
        let s = mk(1000);
        let half = CacheSplit { sm_bytes: s.domain_bytes() * 0.25, reg_bytes: s.domain_bytes() * 0.25 };
        let full = CacheSplit { sm_bytes: s.domain_bytes() * 0.5, reg_bytes: s.domain_bytes() * 0.5 };
        let s_half = speedup(&dev, &s, &half, &tile, EFF_PERKS_LARGE);
        let s_full = speedup(&dev, &s, &full, &tile, EFF_PERKS_LARGE);
        assert!(s_full > s_half, "{s_full} vs {s_half}");
        assert!(s_half > 1.0, "PERKS should win: {s_half}");
        // half-cached speedup in the paper's large-domain ballpark
        assert!(s_half < 2.5, "{s_half}");
        // note: fully caching 75 MB is not physically realizable on A100
        // (35 MB on-chip); the harness never requests such splits, and
        // the projection stays bounded regardless
        assert!(s_full < 12.0, "{s_full}");
    }

    #[test]
    fn smem_bound_when_fully_cached_with_heavy_kernel_traffic() {
        let dev = a100();
        let s = StencilScenario {
            cells: 1024.0 * 1024.0,
            elem: 4,
            radius: 1,
            steps: 1000,
            kernel_smem_per_cell: 4.0,
        };
        let split = CacheSplit { sm_bytes: s.domain_bytes(), reg_bytes: 0.0 };
        let tile = TileGeom::tile_2d(256, 128);
        let gm_only = t_gm(&dev, &s, split.total()) + t_gm_halo(&dev, &s, split.total(), &tile);
        assert!(t_perks(&dev, &s, &split, &tile) > gm_only, "bottleneck must move to smem");
    }
}
