//! MatrixMarket coordinate-format IO (subset: real, general/symmetric).
//!
//! Lets users bring actual SuiteSparse downloads into the CG benches when
//! they have them; the bench harness falls back to the synthetic analogs
//! otherwise.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::sparse::csr::Csr;

/// Read a `.mtx` file (coordinate, real; `general` or `symmetric`).
pub fn read(path: impl AsRef<Path>) -> Result<Csr> {
    let file = std::fs::File::open(path)?;
    read_from(std::io::BufReader::new(file))
}

pub fn read_from(reader: impl BufRead) -> Result<Csr> {
    let mut lines = reader.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::invalid("empty MatrixMarket file"))??;
    let h = header.to_lowercase();
    if !h.starts_with("%%matrixmarket matrix coordinate real") {
        return Err(Error::invalid(format!("unsupported MatrixMarket header: {header}")));
    }
    let symmetric = h.contains("symmetric");

    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| Error::invalid("missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse().map_err(|_| Error::invalid(format!("bad size line {size_line:?}"))))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(Error::invalid(format!("bad size line {size_line:?}")));
    }
    let (nr, nc, nnz) = (dims[0], dims[1], dims[2]);

    let mut trip = Vec::with_capacity(if symmetric { 2 * nnz } else { nnz });
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::invalid(format!("bad entry {t:?}")))?;
        let c: usize = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| Error::invalid(format!("bad entry {t:?}")))?;
        let v: f64 = it.next().and_then(|x| x.parse().ok()).unwrap_or(1.0);
        if r == 0 || c == 0 {
            return Err(Error::invalid("MatrixMarket indices are 1-based"));
        }
        trip.push((r - 1, c - 1, v));
        if symmetric && r != c {
            trip.push((c - 1, r - 1, v));
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(Error::invalid(format!("expected {nnz} entries, found {seen}")));
    }
    Csr::from_coo(nr, nc, trip)
}

/// Write in `general` coordinate format.
pub fn write(csr: &Csr, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "{} {} {}", csr.n_rows, csr.n_cols, csr.nnz())?;
    for r in 0..csr.n_rows {
        let (cols, vals) = csr.row(r);
        for (&c, &v) in cols.iter().zip(vals) {
            writeln!(f, "{} {} {v}", r + 1, c + 1)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn roundtrip_via_tempfile() {
        let a = gen::poisson2d(6);
        let path = std::env::temp_dir().join("perks_mm_roundtrip.mtx");
        write(&a, &path).unwrap();
        let b = read(&path).unwrap();
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn symmetric_expansion() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    2 2 3\n1 1 2.0\n2 1 -1.0\n2 2 2.0\n";
        let a = read_from(std::io::Cursor::new(text)).unwrap();
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.get(0, 1), Some(-1.0));
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn rejects_bad_headers_and_counts() {
        assert!(read_from(std::io::Cursor::new("%%MatrixMarket matrix array real\n1 1\n1.0\n"))
            .is_err());
        assert!(read_from(std::io::Cursor::new(
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n"
        ))
        .is_err());
        assert!(read_from(std::io::Cursor::new(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n"
        ))
        .is_err());
    }
}
