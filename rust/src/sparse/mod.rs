//! Sparse-matrix substrate: CSR storage, MatrixMarket IO, synthetic
//! generators, and the Table V dataset catalog (SuiteSparse analogs).

pub mod csr;
pub mod datasets;
pub mod gen;
pub mod mm;

pub use csr::Csr;
pub use datasets::{by_code, table_v, Dataset};
