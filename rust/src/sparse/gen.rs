//! Synthetic sparse matrix generators.
//!
//! SuiteSparse itself is not available in this environment, so these
//! generators produce SPD matrices with the same row counts and NNZ
//! densities as the paper's Table V datasets (see `datasets.rs` for the
//! catalog). All generated matrices are symmetric positive definite by
//! construction (symmetric pattern + strict diagonal dominance with
//! positive diagonal), so CG converges on them, matching the paper's
//! dataset selection criterion.

use crate::error::Result;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// 5-point Laplacian on a g x g grid (n = g^2, nnz = 5n - 4g).
///
/// Layout matches `python/tests/test_cg.py::_poisson2d` and the nnz
/// formula in `compile/aot.py` exactly — the CG artifacts' shapes are
/// derived from it.
pub fn poisson2d(g: usize) -> Csr {
    let n = g * g;
    let mut trip = Vec::with_capacity(5 * n);
    for i in 0..g {
        for j in 0..g {
            let row = i * g + j;
            trip.push((row, row, 4.0));
            if i > 0 {
                trip.push((row, row - g, -1.0));
            }
            if i + 1 < g {
                trip.push((row, row + g, -1.0));
            }
            if j > 0 {
                trip.push((row, row - 1, -1.0));
            }
            if j + 1 < g {
                trip.push((row, row + 1, -1.0));
            }
        }
    }
    Csr::from_coo(n, n, trip).expect("poisson2d construction")
}

/// 7-point Laplacian on a g^3 grid.
pub fn poisson3d(g: usize) -> Csr {
    let n = g * g * g;
    let idx = |z: usize, y: usize, x: usize| (z * g + y) * g + x;
    let mut trip = Vec::with_capacity(7 * n);
    for z in 0..g {
        for y in 0..g {
            for x in 0..g {
                let row = idx(z, y, x);
                trip.push((row, row, 6.0));
                if z > 0 {
                    trip.push((row, idx(z - 1, y, x), -1.0));
                }
                if z + 1 < g {
                    trip.push((row, idx(z + 1, y, x), -1.0));
                }
                if y > 0 {
                    trip.push((row, idx(z, y - 1, x), -1.0));
                }
                if y + 1 < g {
                    trip.push((row, idx(z, y + 1, x), -1.0));
                }
                if x > 0 {
                    trip.push((row, idx(z, y, x - 1), -1.0));
                }
                if x + 1 < g {
                    trip.push((row, idx(z, y, x + 1), -1.0));
                }
            }
        }
    }
    Csr::from_coo(n, n, trip).expect("poisson3d construction")
}

/// Clustered SPD matrix approximating a FEM-style sparsity: `n` rows with
/// about `avg_row_nnz` entries per row, off-diagonals clustered within a
/// `window` of the diagonal (bandwidth locality like the paper's
/// crankseg/bmwcra datasets). SPD by diagonal dominance.
pub fn clustered_spd(n: usize, avg_row_nnz: usize, window: usize, seed: u64) -> Result<Csr> {
    let mut rng = Rng::new(seed);
    let per_side = avg_row_nnz.saturating_sub(1) / 2;
    let window = window.max(per_side + 1);
    let mut trip: Vec<(usize, usize, f64)> = Vec::with_capacity(n * (1 + 2 * per_side));
    // off-diagonal pattern: for each row choose per_side partners ahead
    for i in 0..n {
        let hi = (i + window).min(n - 1);
        if hi <= i {
            continue;
        }
        for _ in 0..per_side {
            let j = i + 1 + rng.index(hi - i);
            let v = -(0.1 + rng.f64());
            trip.push((i, j, v));
            trip.push((j, i, v));
        }
    }
    // diagonal: strict dominance (duplicates in trip are summed by from_coo,
    // so compute row sums over the summed values after a first pass)
    let pattern = Csr::from_coo(n, n, trip.iter().copied())?;
    let mut diag = vec![0.0f64; n];
    for r in 0..n {
        let (_, vals) = pattern.row(r);
        diag[r] = 1.0 + vals.iter().map(|v| v.abs()).sum::<f64>();
    }
    trip.extend((0..n).map(|i| (i, i, diag[i])));
    Csr::from_coo(n, n, trip)
}

/// Tridiagonal SPD [-1, 2, -1] (the classic 1D Laplacian).
pub fn tridiag(n: usize) -> Csr {
    let mut trip = Vec::with_capacity(3 * n);
    for i in 0..n {
        trip.push((i, i, 2.0));
        if i > 0 {
            trip.push((i, i - 1, -1.0));
        }
        if i + 1 < n {
            trip.push((i, i + 1, -1.0));
        }
    }
    Csr::from_coo(n, n, trip).expect("tridiag construction")
}

/// Ill-conditioned SPD matrix: a symmetric diagonal rescaling
/// `A = S·B·S` of a well-conditioned base `B` (the [-1, 2, -1]
/// tridiagonal Laplacian), with `s_i` swept geometrically from 1 to
/// `sqrt(spread)` in a seed-shuffled row order. The congruence keeps `A`
/// SPD while multiplying its condition number by roughly `spread` — so
/// plain CG stalls as `spread` grows, while Jacobi/block-Jacobi
/// preconditioning (which recovers `B`'s scaling exactly on the
/// diagonal) restores the base convergence rate. This is the
/// ill-conditioned scenario axis for the preconditioner tests/benches.
///
/// Deterministic in `(n, spread, seed)`. `spread` must be >= 1 and
/// finite; `n` must be >= 2.
pub fn ill_conditioned(n: usize, spread: f64, seed: u64) -> Result<Csr> {
    use crate::error::Error;
    if n < 2 {
        return Err(Error::Solver(format!(
            "ill_conditioned needs n >= 2 (got {n})"
        )));
    }
    if !(spread.is_finite() && spread >= 1.0) {
        return Err(Error::Solver(format!(
            "ill_conditioned spread must be finite and >= 1 (got {spread})"
        )));
    }
    // geometric scale ladder, assigned to rows in a shuffled order so the
    // bad scales are not contiguous (contiguity would make block-Jacobi
    // trivially exact)
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(seed);
    for i in (1..n).rev() {
        let j = rng.index(i + 1);
        order.swap(i, j);
    }
    let root = spread.sqrt();
    let step = root.powf(1.0 / (n - 1) as f64);
    let mut scale = vec![0.0f64; n];
    let mut s = 1.0;
    for &row in &order {
        scale[row] = s;
        s *= step;
    }
    let base = tridiag(n);
    let mut trip = Vec::with_capacity(base.nnz());
    for i in 0..n {
        let (cols, vals) = base.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            trip.push((i, j, scale[i] * v * scale[j]));
        }
    }
    Csr::from_coo(n, n, trip)
}

/// Deterministic right-hand side for solver tests/benches.
pub fn rhs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson2d_matches_python_formula() {
        for g in [4, 8, 16, 32] {
            let a = poisson2d(g);
            a.validate().unwrap();
            assert_eq!(a.nnz(), 5 * g * g - 4 * g, "g={g}");
            assert!(a.is_symmetric(0.0));
            assert!(a.is_diag_dominant());
        }
    }

    #[test]
    fn poisson3d_structure() {
        let a = poisson3d(5);
        a.validate().unwrap();
        assert_eq!(a.n_rows, 125);
        assert!(a.is_symmetric(0.0));
        assert!(a.is_diag_dominant());
        // interior row has 7 entries
        let mid = (2 * 5 + 2) * 5 + 2;
        assert_eq!(a.row(mid).0.len(), 7);
    }

    #[test]
    fn clustered_spd_is_spd_shaped() {
        let a = clustered_spd(500, 9, 40, 7).unwrap();
        a.validate().unwrap();
        assert!(a.is_symmetric(1e-12));
        assert!(a.is_diag_dominant());
        let density = a.nnz() as f64 / 500.0;
        assert!(
            (density - 9.0).abs() < 3.0,
            "density {density} too far from target 9"
        );
    }

    #[test]
    fn clustered_deterministic() {
        let a = clustered_spd(100, 5, 10, 3).unwrap();
        let b = clustered_spd(100, 5, 10, 3).unwrap();
        assert_eq!(a, b);
        let c = clustered_spd(100, 5, 10, 4).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn ill_conditioned_is_spd_with_the_requested_spread() {
        let a = ill_conditioned(200, 1e6, 11).unwrap();
        a.validate().unwrap();
        assert!(a.is_symmetric(1e-9));
        let mut lo = f64::MAX;
        let mut hi: f64 = 0.0;
        for i in 0..200 {
            let (cols, vals) = a.row(i);
            let d = vals[cols.iter().position(|&c| c == i).unwrap()];
            assert!(d > 0.0);
            lo = lo.min(d);
            hi = hi.max(d);
        }
        // diagonal spans ~spread (diag of A is 2·s_i², s_i up to √spread)
        assert!(hi / lo > 1e5, "diagonal spread {:.3e} too small", hi / lo);
        // deterministic
        assert_eq!(a, ill_conditioned(200, 1e6, 11).unwrap());
        assert_ne!(a, ill_conditioned(200, 1e6, 12).unwrap());
        // degenerate inputs are rejected
        assert!(ill_conditioned(1, 1e3, 0).is_err());
        assert!(ill_conditioned(10, 0.5, 0).is_err());
        assert!(ill_conditioned(10, f64::NAN, 0).is_err());
    }

    #[test]
    fn jacobi_preconditioning_repairs_ill_conditioning() {
        use crate::cg::precond::Preconditioner;
        use crate::cg::solver::{solve_pipelined, CgOptions};
        let a = ill_conditioned(300, 1e8, 3).unwrap();
        let b = rhs(300, 4);
        let opts = CgOptions { max_iters: 4000, tol: 1e-8, ..Default::default() };
        let plain = solve_pipelined(&a, &b, Preconditioner::None, &opts).unwrap();
        let jac = solve_pipelined(&a, &b, Preconditioner::Jacobi, &opts).unwrap();
        assert!(jac.converged, "Jacobi-preconditioned run must converge");
        assert!(
            jac.iters * 2 < plain.iters || !plain.converged,
            "Jacobi ({}) should need far fewer iterations than plain ({}, converged={})",
            jac.iters,
            plain.iters,
            plain.converged
        );
    }

    #[test]
    fn tridiag_structure() {
        let a = tridiag(10);
        a.validate().unwrap();
        assert_eq!(a.nnz(), 28);
        assert!(a.is_symmetric(0.0));
    }
}
