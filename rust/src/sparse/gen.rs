//! Synthetic sparse matrix generators.
//!
//! SuiteSparse itself is not available in this environment, so these
//! generators produce SPD matrices with the same row counts and NNZ
//! densities as the paper's Table V datasets (see `datasets.rs` for the
//! catalog). All generated matrices are symmetric positive definite by
//! construction (symmetric pattern + strict diagonal dominance with
//! positive diagonal), so CG converges on them, matching the paper's
//! dataset selection criterion.

use crate::error::Result;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// 5-point Laplacian on a g x g grid (n = g^2, nnz = 5n - 4g).
///
/// Layout matches `python/tests/test_cg.py::_poisson2d` and the nnz
/// formula in `compile/aot.py` exactly — the CG artifacts' shapes are
/// derived from it.
pub fn poisson2d(g: usize) -> Csr {
    let n = g * g;
    let mut trip = Vec::with_capacity(5 * n);
    for i in 0..g {
        for j in 0..g {
            let row = i * g + j;
            trip.push((row, row, 4.0));
            if i > 0 {
                trip.push((row, row - g, -1.0));
            }
            if i + 1 < g {
                trip.push((row, row + g, -1.0));
            }
            if j > 0 {
                trip.push((row, row - 1, -1.0));
            }
            if j + 1 < g {
                trip.push((row, row + 1, -1.0));
            }
        }
    }
    Csr::from_coo(n, n, trip).expect("poisson2d construction")
}

/// 7-point Laplacian on a g^3 grid.
pub fn poisson3d(g: usize) -> Csr {
    let n = g * g * g;
    let idx = |z: usize, y: usize, x: usize| (z * g + y) * g + x;
    let mut trip = Vec::with_capacity(7 * n);
    for z in 0..g {
        for y in 0..g {
            for x in 0..g {
                let row = idx(z, y, x);
                trip.push((row, row, 6.0));
                if z > 0 {
                    trip.push((row, idx(z - 1, y, x), -1.0));
                }
                if z + 1 < g {
                    trip.push((row, idx(z + 1, y, x), -1.0));
                }
                if y > 0 {
                    trip.push((row, idx(z, y - 1, x), -1.0));
                }
                if y + 1 < g {
                    trip.push((row, idx(z, y + 1, x), -1.0));
                }
                if x > 0 {
                    trip.push((row, idx(z, y, x - 1), -1.0));
                }
                if x + 1 < g {
                    trip.push((row, idx(z, y, x + 1), -1.0));
                }
            }
        }
    }
    Csr::from_coo(n, n, trip).expect("poisson3d construction")
}

/// Clustered SPD matrix approximating a FEM-style sparsity: `n` rows with
/// about `avg_row_nnz` entries per row, off-diagonals clustered within a
/// `window` of the diagonal (bandwidth locality like the paper's
/// crankseg/bmwcra datasets). SPD by diagonal dominance.
pub fn clustered_spd(n: usize, avg_row_nnz: usize, window: usize, seed: u64) -> Result<Csr> {
    let mut rng = Rng::new(seed);
    let per_side = avg_row_nnz.saturating_sub(1) / 2;
    let window = window.max(per_side + 1);
    let mut trip: Vec<(usize, usize, f64)> = Vec::with_capacity(n * (1 + 2 * per_side));
    // off-diagonal pattern: for each row choose per_side partners ahead
    for i in 0..n {
        let hi = (i + window).min(n - 1);
        if hi <= i {
            continue;
        }
        for _ in 0..per_side {
            let j = i + 1 + rng.index(hi - i);
            let v = -(0.1 + rng.f64());
            trip.push((i, j, v));
            trip.push((j, i, v));
        }
    }
    // diagonal: strict dominance (duplicates in trip are summed by from_coo,
    // so compute row sums over the summed values after a first pass)
    let pattern = Csr::from_coo(n, n, trip.iter().copied())?;
    let mut diag = vec![0.0f64; n];
    for r in 0..n {
        let (_, vals) = pattern.row(r);
        diag[r] = 1.0 + vals.iter().map(|v| v.abs()).sum::<f64>();
    }
    trip.extend((0..n).map(|i| (i, i, diag[i])));
    Csr::from_coo(n, n, trip)
}

/// Tridiagonal SPD [-1, 2, -1] (the classic 1D Laplacian).
pub fn tridiag(n: usize) -> Csr {
    let mut trip = Vec::with_capacity(3 * n);
    for i in 0..n {
        trip.push((i, i, 2.0));
        if i > 0 {
            trip.push((i, i - 1, -1.0));
        }
        if i + 1 < n {
            trip.push((i, i + 1, -1.0));
        }
    }
    Csr::from_coo(n, n, trip).expect("tridiag construction")
}

/// Deterministic right-hand side for solver tests/benches.
pub fn rhs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson2d_matches_python_formula() {
        for g in [4, 8, 16, 32] {
            let a = poisson2d(g);
            a.validate().unwrap();
            assert_eq!(a.nnz(), 5 * g * g - 4 * g, "g={g}");
            assert!(a.is_symmetric(0.0));
            assert!(a.is_diag_dominant());
        }
    }

    #[test]
    fn poisson3d_structure() {
        let a = poisson3d(5);
        a.validate().unwrap();
        assert_eq!(a.n_rows, 125);
        assert!(a.is_symmetric(0.0));
        assert!(a.is_diag_dominant());
        // interior row has 7 entries
        let mid = (2 * 5 + 2) * 5 + 2;
        assert_eq!(a.row(mid).0.len(), 7);
    }

    #[test]
    fn clustered_spd_is_spd_shaped() {
        let a = clustered_spd(500, 9, 40, 7).unwrap();
        a.validate().unwrap();
        assert!(a.is_symmetric(1e-12));
        assert!(a.is_diag_dominant());
        let density = a.nnz() as f64 / 500.0;
        assert!(
            (density - 9.0).abs() < 3.0,
            "density {density} too far from target 9"
        );
    }

    #[test]
    fn clustered_deterministic() {
        let a = clustered_spd(100, 5, 10, 3).unwrap();
        let b = clustered_spd(100, 5, 10, 3).unwrap();
        assert_eq!(a, b);
        let c = clustered_spd(100, 5, 10, 4).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn tridiag_structure() {
        let a = tridiag(10);
        a.validate().unwrap();
        assert_eq!(a.nnz(), 28);
        assert!(a.is_symmetric(0.0));
    }
}
