//! Synthetic analogs of the paper's Table V SuiteSparse datasets.
//!
//! Each entry records the *paper's* rows/NNZ and a generator recipe that
//! reproduces the row count exactly and the NNZ density approximately
//! (within ~15%; CG/SpMV behaviour is governed by n, nnz and row
//! clustering — DESIGN.md §2 documents the substitution). The catalog is
//! scaled by `scale` so CI-sized runs stay fast while benches can run the
//! full sizes.

use crate::error::Result;
use crate::sparse::csr::Csr;
use crate::sparse::gen;

/// One Table V dataset analog.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub code: &'static str,
    pub name: &'static str,
    /// Rows / NNZ as printed in Table V of the paper.
    pub paper_rows: usize,
    pub paper_nnz: usize,
    /// Structure class used by the generator.
    pub class: Class,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    /// Grid Laplacian-like (very sparse, ~5 nnz/row): fv1, ecology2, ...
    Grid2d,
    /// 3D grid-like (~7 nnz/row): thermomech, G2_circuit, ...
    Grid3d,
    /// FEM-like clustered rows (dense rows, 50-200 nnz/row): crankseg, ...
    Fem,
}

/// Table V, D1-D20.
pub fn table_v() -> Vec<Dataset> {
    use Class::*;
    vec![
        Dataset { code: "D1", name: "Trefethen_2000", paper_rows: 2_000, paper_nnz: 41_906, class: Fem },
        Dataset { code: "D2", name: "msc01440", paper_rows: 1_440, paper_nnz: 46_270, class: Fem },
        Dataset { code: "D3", name: "fv1", paper_rows: 9_604, paper_nnz: 85_264, class: Grid2d },
        Dataset { code: "D4", name: "msc04515", paper_rows: 4_515, paper_nnz: 97_707, class: Fem },
        Dataset { code: "D5", name: "Muu", paper_rows: 7_102, paper_nnz: 170_134, class: Fem },
        Dataset { code: "D6", name: "crystm02", paper_rows: 13_965, paper_nnz: 322_905, class: Fem },
        Dataset { code: "D7", name: "shallow_water2", paper_rows: 81_920, paper_nnz: 327_680, class: Grid2d },
        Dataset { code: "D8", name: "finan512", paper_rows: 74_752, paper_nnz: 596_992, class: Grid3d },
        Dataset { code: "D9", name: "cbuckle", paper_rows: 13_681, paper_nnz: 676_515, class: Fem },
        Dataset { code: "D10", name: "G2_circuit", paper_rows: 150_102, paper_nnz: 726_674, class: Grid2d },
        Dataset { code: "D11", name: "thermomech_dM", paper_rows: 204_316, paper_nnz: 1_423_116, class: Grid3d },
        Dataset { code: "D12", name: "ecology2", paper_rows: 999_999, paper_nnz: 4_995_991, class: Grid2d },
        Dataset { code: "D13", name: "tmt_sym", paper_rows: 726_713, paper_nnz: 5_080_961, class: Grid2d },
        Dataset { code: "D14", name: "consph", paper_rows: 83_334, paper_nnz: 6_010_480, class: Fem },
        Dataset { code: "D15", name: "crankseg_1", paper_rows: 52_804, paper_nnz: 10_614_210, class: Fem },
        Dataset { code: "D16", name: "bmwcra_1", paper_rows: 148_770, paper_nnz: 10_644_002, class: Fem },
        Dataset { code: "D17", name: "hood", paper_rows: 220_542, paper_nnz: 10_768_436, class: Fem },
        Dataset { code: "D18", name: "BenElechi1", paper_rows: 245_874, paper_nnz: 13_150_496, class: Fem },
        Dataset { code: "D19", name: "crankseg_2", paper_rows: 63_838, paper_nnz: 14_148_858, class: Fem },
        Dataset { code: "D20", name: "af_1_k101", paper_rows: 503_625, paper_nnz: 17_550_675, class: Fem },
    ]
}

impl Dataset {
    /// Generate the analog matrix, optionally scaled down by `scale`
    /// (rows and nnz divided by `scale`; density preserved).
    pub fn generate(&self, scale: usize) -> Result<Csr> {
        let scale = scale.max(1);
        let n = (self.paper_rows / scale).max(64);
        let nnz_target = (self.paper_nnz / scale).max(n);
        let per_row = (nnz_target as f64 / n as f64).round() as usize;
        let seed = 0xD5_u64
            .wrapping_mul(31)
            .wrapping_add(self.code.bytes().map(|b| b as u64).sum::<u64>());
        match self.class {
            Class::Grid2d => {
                // nearest grid side reproducing n
                let g = (n as f64).sqrt().round() as usize;
                Ok(gen::poisson2d(g.max(8)))
            }
            Class::Grid3d => {
                let g = (n as f64).cbrt().round() as usize;
                Ok(gen::poisson3d(g.max(4)))
            }
            Class::Fem => gen::clustered_spd(n, per_row.max(3), (per_row * 4).max(16), seed),
        }
    }

    /// Matrix footprint in bytes (CSR, f32 values) at paper scale — used
    /// for the L2-capacity split in Fig 7/9.
    pub fn paper_bytes_f32(&self) -> usize {
        self.paper_nnz * 8 + (self.paper_rows + 1) * 4
    }

    /// Paper's Fig 7 splits datasets by whether the problem fits in L2.
    /// With A100's 40 MB L2: D1-D11 are "within", D12-D20 "exceed" —
    /// matching the paper's split at D11/D12.
    pub fn within_l2(&self, l2_bytes: usize) -> bool {
        self.paper_bytes_f32() <= l2_bytes
    }
}

/// Find by code ("D7").
pub fn by_code(code: &str) -> Option<Dataset> {
    table_v().into_iter().find(|d| d.code == code)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_datasets() {
        assert_eq!(table_v().len(), 20);
    }

    #[test]
    fn l2_split_matches_paper_fig7() {
        // Fig 7 splits D1..D11 (within L2) vs D12..D20 (exceed) on A100
        let l2 = 40 * 1024 * 1024;
        for d in table_v() {
            let within = d.within_l2(l2);
            let idx: usize = d.code[1..].parse().unwrap();
            assert_eq!(within, idx <= 11, "{} ({} bytes)", d.code, d.paper_bytes_f32());
        }
    }

    #[test]
    fn generated_analogs_are_spd_and_sized() {
        for code in ["D1", "D3", "D8", "D15"] {
            let d = by_code(code).unwrap();
            let a = d.generate(16).unwrap();
            a.validate().unwrap();
            assert!(a.is_symmetric(1e-12), "{code}");
            assert!(a.is_diag_dominant(), "{code}");
            // density within a factor ~2 of the paper's
            let paper_density = d.paper_nnz as f64 / d.paper_rows as f64;
            let got_density = a.nnz() as f64 / a.n_rows as f64;
            assert!(
                got_density / paper_density < 2.0 && paper_density / got_density < 2.5,
                "{code}: paper {paper_density:.1} vs got {got_density:.1}"
            );
        }
    }

    #[test]
    fn by_code_lookup() {
        assert_eq!(by_code("D12").unwrap().name, "ecology2");
        assert!(by_code("D99").is_none());
    }
}
