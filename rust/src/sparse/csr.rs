//! Compressed Sparse Row matrices.
//!
//! The library's canonical sparse format: `row_ptr` (n+1), `cols` (nnz,
//! sorted within each row), `vals` (nnz). Includes the COO-with-row-ids
//! export used by the CG artifacts (whose signature the python side fixed)
//! and SPD-structure validation for CG inputs.

use crate::error::{Error, Result};

/// A CSR matrix over f64 (converted to f32 at the PJRT edge).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<usize>,
    pub cols: Vec<usize>,
    pub vals: Vec<f64>,
}

impl Csr {
    /// Build from triplets; duplicates are summed, entries sorted per row.
    pub fn from_coo(
        n_rows: usize,
        n_cols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, f64)>,
    ) -> Result<Self> {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n_rows];
        for (r, c, v) in triplets {
            if r >= n_rows || c >= n_cols {
                return Err(Error::invalid(format!("entry ({r},{c}) out of bounds")));
            }
            per_row[r].push((c, v));
        }
        let mut row_ptr = Vec::with_capacity(n_rows + 1);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for row in per_row.iter_mut() {
            row.sort_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < row.len() {
                let (c, mut v) = row[i];
                let mut j = i + 1;
                while j < row.len() && row[j].0 == c {
                    v += row[j].1;
                    j += 1;
                }
                cols.push(c);
                vals.push(v);
                i = j;
            }
            row_ptr.push(cols.len());
        }
        Ok(Self { n_rows, n_cols, row_ptr, cols, vals })
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row slice accessors.
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let lo = self.row_ptr[r];
        let hi = self.row_ptr[r + 1];
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Structural invariants: monotone row_ptr, sorted columns, bounds.
    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.n_rows + 1 || self.row_ptr[0] != 0 {
            return Err(Error::invalid("bad row_ptr head"));
        }
        if *self.row_ptr.last().unwrap() != self.nnz() || self.cols.len() != self.nnz() {
            return Err(Error::invalid("row_ptr tail != nnz"));
        }
        for r in 0..self.n_rows {
            if self.row_ptr[r] > self.row_ptr[r + 1] {
                return Err(Error::invalid(format!("row_ptr not monotone at {r}")));
            }
            let (cols, _) = self.row(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::invalid(format!("row {r}: unsorted/dup columns")));
                }
            }
            if let Some(&c) = cols.last() {
                if c >= self.n_cols {
                    return Err(Error::invalid(format!("row {r}: col {c} out of bounds")));
                }
            }
        }
        Ok(())
    }

    /// Symmetric in structure and values (within `tol`)?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        for r in 0..self.n_rows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                match self.get(c, r) {
                    Some(vt) if (vt - v).abs() <= tol * (1.0 + v.abs()) => {}
                    _ => return false,
                }
            }
        }
        true
    }

    /// Weak diagonal dominance (sufficient condition we use for generated
    /// SPD matrices: symmetric + strictly dominant diag + positive diag).
    pub fn is_diag_dominant(&self) -> bool {
        (0..self.n_rows).all(|r| {
            let (cols, vals) = self.row(r);
            let mut diag = 0.0;
            let mut off = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                if c == r {
                    diag = v;
                } else {
                    off += v.abs();
                }
            }
            diag > 0.0 && diag >= off
        })
    }

    pub fn get(&self, r: usize, c: usize) -> Option<f64> {
        let (cols, vals) = self.row(r);
        cols.binary_search(&c).ok().map(|i| vals[i])
    }

    /// Dense y = A x (gold reference for the SpMV implementations).
    pub fn spmv_gold(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        for r in 0..self.n_rows {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            y[r] = acc;
        }
    }

    /// Export to the COO-with-row-ids arrays the CG artifacts take:
    /// (vals_f32, cols_i32, rows_i32), row-major, sorted within rows —
    /// exactly the layout of the python `_poisson2d` test helper.
    pub fn to_coo_f32(&self) -> (Vec<f32>, Vec<i32>, Vec<i32>) {
        let mut data = Vec::with_capacity(self.nnz());
        let mut cols = Vec::with_capacity(self.nnz());
        let mut rows = Vec::with_capacity(self.nnz());
        for r in 0..self.n_rows {
            let (cs, vs) = self.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                data.push(v as f32);
                cols.push(c as i32);
                rows.push(r as i32);
            }
        }
        (data, cols, rows)
    }

    /// Size of the matrix data in bytes at a given element size (CSR:
    /// vals + cols index + row_ptr).
    pub fn bytes(&self, elem: usize) -> usize {
        self.nnz() * (elem + 4) + (self.n_rows + 1) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [2 -1 0; -1 2 -1; 0 -1 2]
        Csr::from_coo(
            3,
            3,
            vec![
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn build_and_validate() {
        let a = small();
        a.validate().unwrap();
        assert_eq!(a.nnz(), 7);
        assert_eq!(a.get(1, 2), Some(-1.0));
        assert_eq!(a.get(0, 2), None);
    }

    #[test]
    fn duplicates_summed() {
        let a = Csr::from_coo(2, 2, vec![(0, 0, 1.0), (0, 0, 2.5)]).unwrap();
        assert_eq!(a.get(0, 0), Some(3.5));
        assert_eq!(a.nnz(), 1);
    }

    #[test]
    fn out_of_bounds_rejected() {
        assert!(Csr::from_coo(2, 2, vec![(2, 0, 1.0)]).is_err());
    }

    #[test]
    fn symmetry_and_dominance() {
        let a = small();
        assert!(a.is_symmetric(0.0));
        assert!(a.is_diag_dominant());
        let b = Csr::from_coo(2, 2, vec![(0, 1, 1.0), (1, 1, 1.0)]).unwrap();
        assert!(!b.is_symmetric(0.0));
    }

    #[test]
    fn spmv_gold_matches_dense() {
        let a = small();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        a.spmv_gold(&x, &mut y);
        assert_eq!(y, [0.0, 0.0, 4.0]);
    }

    #[test]
    fn coo_export_row_major_sorted() {
        let a = small();
        let (data, cols, rows) = a.to_coo_f32();
        assert_eq!(rows, vec![0, 0, 1, 1, 1, 2, 2]);
        assert_eq!(cols, vec![0, 1, 0, 1, 2, 1, 2]);
        assert_eq!(data[0], 2.0);
    }
}
