//! SpMV substrate: merge-based SpMV (Merrill-Garland) as adopted by the
//! paper's CG solver, plus the naive row-split baseline.

pub mod merge;
pub mod naive;

pub use merge::{merge_path_search, Coord, MergePlan};
