//! Merge-based SpMV (Merrill & Garland, SC'16), implemented from scratch.
//!
//! The paper's CG solver replaces the naive SpMV with CUB's merge-based
//! SpMV because its two-level *search* decomposition fits the PERKS
//! caching scheme (§V-C): the coordinate path of length (n_rows + nnz) is
//! split into equal shares, and a 2D binary search ("merge-path search")
//! finds each share's (row, nonzero) start. The TB-level search results
//! are exactly what the paper caches in its "workload" policies.
//!
//! This rust implementation is the CPU hot path of the CG substrate: the
//! merge path is searched once per matrix (cacheable — the matrix is
//! static across iterations, as the paper exploits), then each worker
//! consumes its share with perfectly balanced work regardless of row
//! length skew.

use crate::sparse::csr::Csr;

/// A merge-path coordinate: position on the (row-end, nonzero) diagonal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coord {
    /// Index into `row_ptr[1..]` (i.e., current row).
    pub row: usize,
    /// Index into the nonzero arrays.
    pub nz: usize,
}

/// 2D merge-path search: find the coordinate where `diagonal` splits the
/// merge of `row_end[0..n_rows]` and the natural numbers `0..nnz`.
///
/// Standard merge-path: binary search the largest `row` such that
/// `row_end[row'] <= diagonal - row' - 1` holds for all `row' < row`.
pub fn merge_path_search(diagonal: usize, row_end: &[usize], nnz: usize) -> Coord {
    let mut lo = diagonal.saturating_sub(nnz);
    let mut hi = diagonal.min(row_end.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        if row_end[mid] <= diagonal - mid - 1 {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    Coord { row: lo, nz: diagonal - lo }
}

/// The cached "TB-level search result" of the paper: share boundaries.
#[derive(Clone, Debug)]
pub struct MergePlan {
    pub shares: Vec<Coord>,
    pub n_rows: usize,
    pub nnz: usize,
}

impl MergePlan {
    /// Partition the merge path into `parts` equal shares.
    pub fn new(csr: &Csr, parts: usize) -> Self {
        let parts = parts.max(1);
        let n = csr.n_rows;
        let nnz = csr.nnz();
        let path_len = n + nnz;
        let row_end = &csr.row_ptr[1..];
        let mut shares = Vec::with_capacity(parts + 1);
        for p in 0..=parts {
            let diagonal = (path_len * p) / parts;
            shares.push(merge_path_search(diagonal, row_end, nnz));
        }
        Self { shares, n_rows: n, nnz }
    }

    /// Items (rows + nonzeros) in share `i` — balanced by construction.
    pub fn share_items(&self, i: usize) -> usize {
        let a = self.shares[i];
        let b = self.shares[i + 1];
        (b.row - a.row) + (b.nz - a.nz)
    }

    pub fn parts(&self) -> usize {
        self.shares.len() - 1
    }
}

/// Sequential consumption of one merge share: rows [start.row, end.row)
/// are completed inside the share; a trailing partial row accumulates into
/// `carry` which the caller combines (the "fixup" pass of the paper).
///
/// Safe single-writer wrapper over [`consume_share_raw`].
pub(crate) fn consume_share(
    csr: &Csr,
    x: &[f64],
    y: &mut [f64],
    start: Coord,
    end: Coord,
) -> (usize, f64) {
    debug_assert!(y.len() >= end.row);
    // SAFETY: `y` is exclusively borrowed and long enough for every
    // completed row of the share.
    unsafe { consume_share_raw(csr, x, y.as_mut_ptr(), start, end) }
}

/// Raw-pointer form of the share consumption, shared by the concurrent
/// consumers (`spmv_parallel`'s scoped workers and `cg::pool`'s resident
/// workers): each share writes a disjoint set of complete rows, and going
/// through the pointer — instead of overlapping `&mut [f64]` views — keeps
/// that concurrent disjoint-write protocol free of aliased exclusive
/// references.
///
/// SAFETY: `y` must be valid for writes at every index in
/// `[start.row, end.row)`, and no other thread may concurrently touch
/// those rows.
pub(crate) unsafe fn consume_share_raw(
    csr: &Csr,
    x: &[f64],
    y: *mut f64,
    start: Coord,
    end: Coord,
) -> (usize, f64) {
    let row_end = &csr.row_ptr[1..];
    let mut row = start.row;
    let mut nz = start.nz;
    let mut acc = 0.0;
    let vals = &csr.vals;
    let cols = &csr.cols;
    while row < end.row {
        // finish this row: iterate the contiguous (val, col) segment so
        // the compiler drops the per-element bounds checks
        let hi = row_end[row];
        for (v, &c) in vals[nz..hi].iter().zip(&cols[nz..hi]) {
            acc += v * x[c];
        }
        nz = hi;
        y.add(row).write(acc);
        acc = 0.0;
        row += 1;
    }
    // partial tail row (completed by a later share / fixup)
    for (v, &c) in vals[nz..end.nz].iter().zip(&cols[nz..end.nz]) {
        acc += v * x[c];
    }
    (row, acc)
}

/// y = A x using the merge plan, sequential over shares (the share loop is
/// embarrassingly parallel; `spmv_parallel` threads it).
pub fn spmv(csr: &Csr, plan: &MergePlan, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(plan.n_rows, csr.n_rows);
    y[..csr.n_rows].fill(0.0);
    let mut carries: Vec<(usize, f64)> = Vec::with_capacity(plan.parts());
    for i in 0..plan.parts() {
        let (row, carry) = consume_share(csr, x, y, plan.shares[i], plan.shares[i + 1]);
        carries.push((row, carry));
    }
    // fixup: add partial-row carries
    for (row, carry) in carries {
        if row < csr.n_rows && carry != 0.0 {
            y[row] += carry;
        }
    }
}

/// Threaded variant: shares are distributed over `workers` OS threads (a
/// share is the work *unit*; the thread count is the worker pool —
/// spawning per share would drown the balanced work in spawn latency).
///
/// `workers == 0` falls back to `available_parallelism`; solvers that call
/// this per iteration should resolve their worker count **once** and pass
/// it in, so the split stays consistent with their `threads` knob and the
/// sysconf query is not re-paid on every SpMV (see `session::cpu::CpuCg`).
///
/// Note this spawns (and joins) `workers` threads per call — the relaunch
/// overhead the paper's persistent model eliminates. `cg::pool::CgPool`
/// consumes the same shares from spawn-once resident workers instead.
pub fn spmv_parallel(csr: &Csr, plan: &MergePlan, x: &[f64], y: &mut [f64], workers: usize) {
    let parts = plan.parts();
    let workers = crate::util::resolve_workers(workers).min(parts);
    if parts == 1 || workers == 1 {
        return spmv(csr, plan, x, y);
    }
    crate::util::counters::note_thread_spawns(workers as u64);
    y[..csr.n_rows].fill(0.0);
    // each share writes rows [start.row, end.row) — disjoint by
    // construction; carries are combined after the join
    let mut carries = vec![(0usize, 0.0f64); parts];
    let y_ptr = SendPtr(y.as_mut_ptr());
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let y_ptr = y_ptr;
            let shares = &plan.shares;
            // worker w consumes shares [lo, hi) — balanced because the
            // shares themselves are item-balanced
            let lo = parts * w / workers;
            let hi = parts * (w + 1) / workers;
            handles.push(scope.spawn(move || {
                // SAFETY: shares own disjoint complete-row ranges; the
                // trailing partial row is returned as a carry, not
                // written. Writes go through the raw pointer, so no
                // aliased exclusive references exist across workers.
                (lo..hi)
                    .map(|i| unsafe {
                        consume_share_raw(csr, x, y_ptr.get(), shares[i], shares[i + 1])
                    })
                    .collect::<Vec<_>>()
            }));
        }
        for (w, h) in handles.into_iter().enumerate() {
            let lo = parts * w / workers;
            for (i, c) in h.join().unwrap().into_iter().enumerate() {
                carries[lo + i] = c;
            }
        }
    });
    for (row, carry) in carries {
        if row < csr.n_rows && carry != 0.0 {
            y[row] += carry;
        }
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
// SAFETY: the pointer targets a buffer that outlives the scoped threads,
// and each thread writes only its own disjoint `[lo, hi)` share.
unsafe impl Send for SendPtr {}

impl SendPtr {
    /// Method access forces whole-struct closure capture (a bare field
    /// access would capture only the non-Send raw pointer under RFC 2229).
    fn get(&self) -> *mut f64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::check::{allclose, forall, Prop};
    use crate::util::rng::Rng;

    fn gold(csr: &Csr, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; csr.n_rows];
        csr.spmv_gold(x, &mut y);
        y
    }

    #[test]
    fn matches_gold_poisson() {
        let a = gen::poisson2d(16);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..a.n_rows).map(|_| rng.f64()).collect();
        let want = gold(&a, &x);
        for parts in [1, 2, 7, 32] {
            let plan = MergePlan::new(&a, parts);
            let mut y = vec![0.0; a.n_rows];
            spmv(&a, &plan, &x, &mut y);
            if let Prop::Fail(m) = allclose(&y, &want, 1e-12, 1e-12) {
                panic!("parts={parts}: {m}");
            }
            let mut yp = vec![0.0; a.n_rows];
            spmv_parallel(&a, &plan, &x, &mut yp, 0);
            if let Prop::Fail(m) = allclose(&yp, &want, 1e-12, 1e-12) {
                panic!("parallel parts={parts}: {m}");
            }
        }
    }

    #[test]
    fn handles_skewed_rows() {
        // one huge row among tiny ones — naive row-split would imbalance;
        // merge split must stay correct
        let n = 64;
        let mut trip = vec![];
        for j in 0..n {
            trip.push((0, j, 1.0 + j as f64));
        }
        for i in 1..n {
            trip.push((i, i, 2.0));
        }
        let a = Csr::from_coo(n, n, trip).unwrap();
        let x: Vec<f64> = (0..n).map(|i| i as f64 * 0.5).collect();
        let want = gold(&a, &x);
        let plan = MergePlan::new(&a, 8);
        let mut y = vec![0.0; n];
        spmv_parallel(&a, &plan, &x, &mut y, 4);
        if let Prop::Fail(m) = allclose(&y, &want, 1e-12, 1e-12) {
            panic!("{m}");
        }
    }

    #[test]
    fn shares_are_balanced() {
        let a = gen::clustered_spd(2000, 11, 50, 1).unwrap();
        let parts = 16;
        let plan = MergePlan::new(&a, parts);
        let items: Vec<usize> = (0..parts).map(|i| plan.share_items(i)).collect();
        let max = *items.iter().max().unwrap();
        let min = *items.iter().min().unwrap();
        // merge-path guarantee: shares differ by at most 1 item
        assert!(max - min <= 1, "imbalance: {items:?}");
    }

    #[test]
    fn search_endpoints() {
        let a = gen::poisson2d(4);
        let row_end = &a.row_ptr[1..];
        let c0 = merge_path_search(0, row_end, a.nnz());
        assert_eq!(c0, Coord { row: 0, nz: 0 });
        let cend = merge_path_search(a.n_rows + a.nnz(), row_end, a.nnz());
        assert_eq!(cend, Coord { row: a.n_rows, nz: a.nnz() });
    }

    #[test]
    fn property_random_matrices_match_gold() {
        forall(
            0xC0FFEE,
            15,
            |rng| {
                let n = 20 + rng.index(100);
                let per_row = 3 + rng.index(8);
                let a = gen::clustered_spd(n, per_row, 12, rng.next_u64()).unwrap();
                let x: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect();
                let parts = 1 + rng.index(12);
                (a, x, parts)
            },
            |(a, x, parts)| {
                let want = gold(a, x);
                let plan = MergePlan::new(a, *parts);
                let mut y = vec![0.0; a.n_rows];
                spmv_parallel(a, &plan, x, &mut y, 3);
                allclose(&y, &want, 1e-11, 1e-11)
            },
        );
    }

    #[test]
    fn empty_rows_ok() {
        // rows with zero entries exercise merge-path row advancement
        let a = Csr::from_coo(5, 5, vec![(0, 0, 1.0), (4, 4, 2.0)]).unwrap();
        let x = vec![1.0; 5];
        let want = gold(&a, &x);
        let plan = MergePlan::new(&a, 3);
        let mut y = vec![0.0; 5];
        spmv(&a, &plan, &x, &mut y);
        assert_eq!(y, want);
    }
}
