//! Naive row-per-worker SpMV — the baseline the paper's CG sample used
//! before adopting merge-based SpMV (§V-C). Kept as the comparison point
//! for the SpMV ablation bench: it is simple but imbalanced under row-
//! length skew.

use crate::sparse::csr::Csr;
use crate::stencil::parallel::partition;

/// Sequential y = A x.
pub fn spmv(csr: &Csr, x: &[f64], y: &mut [f64]) {
    csr.spmv_gold(x, y);
}

/// Threaded y = A x with a row-block split (NOT work-balanced: a block
/// holding dense rows dominates the critical path — this is the imbalance
/// merge-path removes).
pub fn spmv_parallel(csr: &Csr, x: &[f64], y: &mut [f64], threads: usize) {
    let bands = partition(csr.n_rows, threads.max(1));
    // disjoint row ranges => disjoint y slices
    let mut rest: &mut [f64] = y;
    let mut slices: Vec<(usize, &mut [f64])> = Vec::with_capacity(bands.len());
    let mut cut = 0;
    for &(start, len) in &bands {
        debug_assert_eq!(start, cut);
        let (head, tail) = rest.split_at_mut(len);
        slices.push((start, head));
        rest = tail;
        cut += len;
    }
    std::thread::scope(|scope| {
        for (start, slice) in slices {
            scope.spawn(move || {
                for (i, out) in slice.iter_mut().enumerate() {
                    let r = start + i;
                    let lo = csr.row_ptr[r];
                    let hi = csr.row_ptr[r + 1];
                    let mut acc = 0.0;
                    for k in lo..hi {
                        acc += csr.vals[k] * x[csr.cols[k]];
                    }
                    *out = acc;
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::rng::Rng;

    #[test]
    fn parallel_matches_sequential() {
        let a = gen::poisson2d(12);
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..a.n_rows).map(|_| rng.f64()).collect();
        let mut want = vec![0.0; a.n_rows];
        spmv(&a, &x, &mut want);
        for threads in [1, 3, 8] {
            let mut got = vec![0.0; a.n_rows];
            spmv_parallel(&a, &x, &mut got, threads);
            assert_eq!(got, want, "threads={threads}");
        }
    }
}
