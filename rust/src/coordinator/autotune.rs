//! Occupancy autotuner (paper §V-E-1: "an end-user only needs to reduce
//! the device occupancy to minimum (while maintaining performance) via
//! manual tuning of the kernel launch parameters or using auto-tuning
//! tools").
//!
//! Three tuners:
//! * `tune_occupancy` — over the simulator: find the minimum TB/SMX whose
//!   modeled efficiency stays within `slack` of the saturated rate, and
//!   report the capacity freed for caching;
//! * `tune_threads` — over the CPU persistent-threads executor: measure a
//!   small sweep and pick the thread count with the best wall time (used
//!   by the examples and benches to avoid hardcoding 8);
//! * `tune_exec_mode` — generic execution-model picker behind
//!   `session::ExecPolicy::Auto`: measure (or model) each candidate mode
//!   through a caller-supplied probe and keep the fastest.

use crate::coordinator::executor::ExecMode;
use crate::simgpu::concurrency;
use crate::simgpu::device::DeviceSpec;
use crate::simgpu::occupancy::{self, KernelResources};
use crate::stencil::grid::Domain;
use crate::stencil::parallel;
use crate::stencil::shape::StencilSpec;

/// Result of the simulator-side occupancy tuning.
#[derive(Clone, Debug)]
pub struct OccupancyChoice {
    pub tb_per_smx: usize,
    /// Modeled efficiency at that occupancy (1.0 = saturated).
    pub efficiency: f64,
    /// Bytes freed device-wide for PERKS caching.
    pub freed_bytes: usize,
}

/// Find the minimum occupancy whose efficiency >= (1 - slack) of the
/// saturated one, maximizing freed resources (the paper's procedure in
/// §IV-D / Table II: drop to 1/4 occupancy while keeping performance).
pub fn tune_occupancy(
    dev: &DeviceSpec,
    kr: &KernelResources,
    ilp_bytes_per_tb: f64,
    l2_hit_rate: f64,
    slack: f64,
) -> Option<OccupancyChoice> {
    let c_hw = concurrency::c_hw_blended(dev, l2_hit_rate);
    let max_tb = occupancy::max_tb_per_smx(dev, kr);
    if max_tb == 0 {
        return None;
    }
    let eff_at = |tb: usize| concurrency::efficiency(ilp_bytes_per_tb * tb as f64, c_hw);
    let saturated = eff_at(max_tb);
    let mut best: Option<OccupancyChoice> = None;
    for tb in 1..=max_tb {
        let eff = eff_at(tb);
        if eff >= (1.0 - slack) * saturated {
            let occ = occupancy::occupancy(dev, kr, tb)?;
            best = Some(OccupancyChoice {
                tb_per_smx: tb,
                efficiency: eff,
                freed_bytes: occ.free_bytes_device(dev),
            });
            break; // lowest TB/SMX satisfying the bound frees the most
        }
    }
    best.or_else(|| {
        let occ = occupancy::occupancy(dev, kr, max_tb)?;
        Some(OccupancyChoice {
            tb_per_smx: max_tb,
            efficiency: saturated,
            freed_bytes: occ.free_bytes_device(dev),
        })
    })
}

/// Result of the measured CPU thread tuning.
#[derive(Clone, Debug)]
pub struct ThreadChoice {
    pub threads: usize,
    pub wall_seconds: f64,
    /// All measured (threads, seconds) points.
    pub sweep: Vec<(usize, f64)>,
}

/// Measure the persistent executor over a thread sweep (powers of two up
/// to `max_threads`) on a short calibration run and pick the fastest.
pub fn tune_threads(
    spec: &StencilSpec,
    domain: &Domain,
    calib_steps: usize,
    max_threads: usize,
) -> crate::error::Result<ThreadChoice> {
    let mut sweep = Vec::new();
    let mut t = 1;
    while t <= max_threads {
        let rep = parallel::persistent(spec, domain, calib_steps, t)?;
        sweep.push((t, rep.wall_seconds));
        t *= 2;
    }
    let &(threads, wall_seconds) = sweep
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("non-empty sweep");
    Ok(ThreadChoice { threads, wall_seconds, sweep })
}

/// Result of the execution-model tuning.
#[derive(Clone, Debug)]
pub struct ModeChoice {
    pub mode: ExecMode,
    /// Per-step (or per-iteration) cost of the winning mode, as reported
    /// by the probe.
    pub cost: f64,
    /// All probed (mode, cost) points.
    pub sweep: Vec<(ExecMode, f64)>,
}

/// Probe every candidate execution model with `measure` (which returns a
/// comparable cost — typically seconds per step, measured or modeled) and
/// pick the cheapest. Used by `session::ExecPolicy::Auto`.
pub fn tune_exec_mode<F>(candidates: &[ExecMode], mut measure: F) -> crate::error::Result<ModeChoice>
where
    F: FnMut(ExecMode) -> crate::error::Result<f64>,
{
    if candidates.is_empty() {
        return Err(crate::error::Error::invalid("no candidate execution modes"));
    }
    let mut sweep = Vec::with_capacity(candidates.len());
    for &m in candidates {
        sweep.push((m, measure(m)?));
    }
    let &(mode, cost) = sweep
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("non-empty sweep");
    Ok(ModeChoice { mode, cost, sweep })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::a100;
    use crate::stencil::shape;

    #[test]
    fn tuner_matches_table_ii_quarter_occupancy() {
        // Table II: the sp 2d5pt kernel can drop to 1/4 of max occupancy
        // (TB/SMX 2 of 8) while maintaining performance
        let dev = a100();
        let kr = KernelResources { threads_per_tb: 256, regs_per_thread: 32, smem_per_tb: 0 };
        let choice = tune_occupancy(&dev, &kr, (2580.0 + 2048.0) * 4.0 / 5.0, 0.6, 0.05).unwrap();
        assert!(choice.tb_per_smx <= 2, "tuner picked {}", choice.tb_per_smx);
        assert!(choice.efficiency > 0.9);
        assert!(choice.freed_bytes > 0);
    }

    #[test]
    fn lower_occupancy_frees_more() {
        let dev = a100();
        let kr = KernelResources { threads_per_tb: 256, regs_per_thread: 32, smem_per_tb: 1024 };
        // generous slack => TB/SMX = 1 => max freed
        let loose = tune_occupancy(&dev, &kr, 1e9, 0.0, 0.5).unwrap();
        assert_eq!(loose.tb_per_smx, 1);
        let tight = tune_occupancy(&dev, &kr, 500.0, 0.0, 0.0).unwrap();
        assert!(tight.tb_per_smx >= loose.tb_per_smx);
        assert!(loose.freed_bytes >= tight.freed_bytes);
    }

    #[test]
    fn kernel_too_fat_returns_none() {
        let dev = a100();
        let kr = KernelResources {
            threads_per_tb: 2048,
            regs_per_thread: 256,
            smem_per_tb: usize::MAX / 2,
        };
        assert!(tune_occupancy(&dev, &kr, 1.0, 0.0, 0.1).is_none());
    }

    #[test]
    fn mode_tuner_picks_cheapest_and_reports_sweep() {
        let costs = |m: ExecMode| match m {
            ExecMode::HostLoop => 3.0,
            ExecMode::HostLoopResident => 2.0,
            ExecMode::Persistent => 1.0,
            ExecMode::Pipelined => 0.5,
        };
        let choice = tune_exec_mode(&ExecMode::all(), |m| Ok(costs(m))).unwrap();
        assert_eq!(choice.mode, ExecMode::Pipelined);
        assert_eq!(choice.cost, 0.5);
        assert_eq!(choice.sweep.len(), 4);
        assert!(tune_exec_mode(&[], |_| Ok(0.0)).is_err());
        // probe failures propagate
        assert!(tune_exec_mode(&[ExecMode::HostLoop], |_| {
            Err(crate::error::Error::invalid("boom"))
        })
        .is_err());
    }

    #[test]
    fn thread_tuner_returns_a_measured_choice() {
        let s = shape::spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[64, 64]).unwrap();
        d.randomize(5);
        let choice = tune_threads(&s, &d, 4, 4).unwrap();
        assert!(choice.threads == 1 || choice.threads == 2 || choice.threads == 4);
        assert_eq!(choice.sweep.len(), 3);
        assert!(choice.wall_seconds <= choice.sweep[0].1 + 1e-12);
    }
}
