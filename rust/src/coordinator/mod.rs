//! Layer-3 coordinator: the PERKS execution model.
//!
//! * `executor` — host-loop vs persistent drivers over PJRT artifacts
//!   (the engine behind `session::Backend::Pjrt`; constructed only
//!   through `session::SessionBuilder`);
//! * `autotune` — occupancy, thread-count and execution-model tuners
//!   (the machinery behind `session::ExecPolicy::Auto`);
//! * `caching`  — the paper's §III-B caching policy engine;
//! * `barrier`  — grid-sync semantics for the CPU persistent-threads
//!   substrate (`stencil::parallel`).

pub mod autotune;
pub mod barrier;
pub mod caching;
pub mod executor;
pub mod multidev;
pub mod profile;

pub use caching::{CacheLocation, CachePlan, CacheableArray};
pub use executor::{CgDriver, CgReport, ExecMode, RunReport, StencilDriver};
pub use profile::AccessProfile;
