//! Device-wide barrier semantics for the CPU persistent-threads executor.
//!
//! The paper's persistent kernel synchronizes time steps with CUDA's grid
//! sync. Our CPU analog (`stencil::parallel`) runs one OS thread per
//! "thread block" for the whole solve; this module provides the grid-sync
//! equivalent: a reusable barrier with generation counting, plus launch
//! statistics so benches can report barrier cost vs relaunch cost
//! (cf. Zhang et al. [32] in the paper: the two are comparable).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

/// A grid barrier: `sync()` blocks until all participants arrive.
pub struct GridBarrier {
    inner: Barrier,
    generation: AtomicU64,
    participants: usize,
    /// Cumulative nanoseconds threads spent waiting (summed over threads).
    wait_ns: AtomicU64,
}

impl GridBarrier {
    pub fn new(participants: usize) -> Self {
        Self {
            inner: Barrier::new(participants),
            generation: AtomicU64::new(0),
            participants,
            wait_ns: AtomicU64::new(0),
        }
    }

    pub fn participants(&self) -> usize {
        self.participants
    }

    /// Block until all participants arrive; returns the completed
    /// generation index (number of grid syncs so far).
    pub fn sync(&self) -> u64 {
        let t0 = std::time::Instant::now();
        let res = self.inner.wait();
        self.wait_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if res.is_leader() {
            self.generation.fetch_add(1, Ordering::Relaxed);
        }
        self.generation.load(Ordering::Relaxed)
    }

    pub fn generations(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Total time threads spent blocked at the barrier (sum over threads).
    pub fn total_wait(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.wait_ns.load(Ordering::Relaxed))
    }
}

/// Serialized stderr-style progress log shared by persistent threads
/// (ordinary printing interleaves; solver code must stay lock-free, so
/// only coordinator-level events go through this).
#[derive(Default)]
pub struct EventLog {
    events: Mutex<Vec<String>>,
}

impl EventLog {
    pub fn push(&self, msg: impl Into<String>) {
        self.events.lock().unwrap().push(msg.into());
    }

    pub fn drain(&self) -> Vec<String> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn barrier_synchronizes_counters() {
        // Each thread increments a shared epoch counter only after sync;
        // with a correct barrier no thread can run ahead.
        let n = 4;
        let steps = 50;
        let barrier = Arc::new(GridBarrier::new(n));
        let epoch = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let b = barrier.clone();
                let e = epoch.clone();
                std::thread::spawn(move || {
                    for step in 0..steps {
                        // everyone sees epoch == step * n threads' worth
                        let seen = e.load(Ordering::SeqCst);
                        assert!(seen >= (step as u64) * n as u64);
                        e.fetch_add(1, Ordering::SeqCst);
                        b.sync();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(epoch.load(Ordering::SeqCst), (n * steps) as u64);
        assert_eq!(barrier.generations(), steps as u64);
    }

    #[test]
    fn event_log_collects() {
        let log = EventLog::default();
        log.push("a");
        log.push("b");
        assert_eq!(log.drain(), vec!["a".to_string(), "b".to_string()]);
        assert!(log.drain().is_empty());
    }
}
