//! Device-wide barrier semantics for the CPU persistent-threads executor.
//!
//! The paper's persistent kernel synchronizes time steps with CUDA's grid
//! sync. Our CPU analog (`stencil::parallel`, `cg::pool`) runs one OS
//! thread per "thread block" for the whole solve; this module provides the
//! grid-sync equivalent: a reusable barrier with generation counting, plus
//! launch statistics so benches can report barrier cost vs relaunch cost
//! (cf. Zhang et al. [32] in the paper: the two are comparable).
//!
//! Beyond plain synchronization, the barrier carries a **deterministic
//! all-reduce** ([`GridBarrier::sync_sum`]): the CPU analog of the
//! grid-sync + device-wide reduction a persistent CG kernel uses for its
//! dot products. Participants publish partial sums into fixed slots
//! ([`GridBarrier::put`]); after the barrier every participant folds the
//! slots in *slot-index order*, so the result is a pure function of the
//! slot contents — bit-identical regardless of thread arrival order or
//! worker count. Sizing the slot array by logical work blocks rather than
//! by participants (see [`GridBarrier::with_reduction`]) is what lets the
//! pooled CG solver walk the same iterates at every thread count.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

/// A grid barrier: `sync()` blocks until all participants arrive.
pub struct GridBarrier {
    inner: Barrier,
    generation: AtomicU64,
    /// Reduction generations only ([`GridBarrier::sync_reduce`]) — the
    /// per-barrier counter behind the barriers-per-iteration invariant.
    reductions: AtomicU64,
    participants: usize,
    /// Cumulative nanoseconds threads spent waiting (summed over threads).
    wait_ns: AtomicU64,
    /// All-reduce slots (f64 bit patterns), folded in index order.
    slots: Vec<AtomicU64>,
}

impl GridBarrier {
    pub fn new(participants: usize) -> Self {
        Self::with_reduction(participants, participants)
    }

    /// A barrier whose all-reduce carries `width` slots. `width` usually
    /// equals `participants` (one partial per thread), but reductions that
    /// must be invariant to the thread count publish one partial per
    /// *logical block* instead, with each thread owning a fixed block
    /// range — the pooled CG dot products do exactly that.
    pub fn with_reduction(participants: usize, width: usize) -> Self {
        Self {
            inner: Barrier::new(participants),
            generation: AtomicU64::new(0),
            reductions: AtomicU64::new(0),
            participants,
            wait_ns: AtomicU64::new(0),
            slots: (0..width).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn participants(&self) -> usize {
        self.participants
    }

    /// Block until all participants arrive; returns the completed
    /// generation index (number of grid syncs so far). Each completed
    /// generation is also reported once (by the leader) to the
    /// process-wide [`crate::util::counters::barrier_syncs`] counter, the
    /// sync analog of the thread-spawn counter.
    pub fn sync(&self) -> u64 {
        self.sync_is_leader();
        self.generation.load(Ordering::Relaxed)
    }

    pub fn generations(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Number of all-reduce slots (see [`GridBarrier::with_reduction`]).
    pub fn reduction_width(&self) -> usize {
        self.slots.len()
    }

    /// Publish a partial sum into reduction slot `slot`. Every slot must
    /// be (re)written by exactly one participant before the matching
    /// [`GridBarrier::sync_sum`]; the slot assignment is the caller's
    /// protocol (participant index, or logical block index for
    /// thread-count-invariant reductions).
    pub fn put(&self, slot: usize, value: f64) {
        self.slots[slot].store(value.to_bits(), Ordering::Release);
    }

    /// Like [`GridBarrier::sync`], but the completed generation is a
    /// **slot-ordered reduction generation**: every participant's `put`s
    /// are published and will be folded after this sync. The leader
    /// additionally reports the generation to
    /// [`crate::util::counters::barrier_reductions`] — the counter behind
    /// the barriers-per-iteration invariant (classic CG pays two
    /// reduction generations per iteration, pipelined CG pays one).
    pub fn sync_reduce(&self) -> u64 {
        let led = self.sync_is_leader();
        self.reductions.fetch_add(led, Ordering::Relaxed);
        crate::util::counters::note_barrier_reductions(led);
        self.generation.load(Ordering::Relaxed)
    }

    /// Completed **reduction** generations only (the `sync_reduce`
    /// subset of [`GridBarrier::generations`]) — exact per barrier even
    /// when other pools run concurrently, so tests assert the
    /// barriers-per-iteration invariant with equality: classic CG pays
    /// two reduction generations per iteration, pipelined CG pays one.
    pub fn reduction_generations(&self) -> u64 {
        self.reductions.load(Ordering::Relaxed)
    }

    /// `sync()` returning 1 exactly on the leader (0 elsewhere), so
    /// leader-side accounting composes without re-deriving leadership.
    fn sync_is_leader(&self) -> u64 {
        let t0 = std::time::Instant::now();
        let res = self.inner.wait();
        self.wait_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if res.is_leader() {
            self.generation.fetch_add(1, Ordering::Relaxed);
            crate::util::counters::note_barrier_syncs(1);
            1
        } else {
            0
        }
    }

    /// Device-wide all-reduce: wait for every participant (so all `put`s
    /// are visible), fold **all** slots in slot-index order, then wait
    /// again so the slots may be reused by the next reduction. Every
    /// participant returns the same bit pattern, and the result does not
    /// depend on arrival order: the fold order is fixed by slot index.
    /// The first sync is a reduction generation (see
    /// [`GridBarrier::sync_reduce`]).
    pub fn sync_sum(&self) -> f64 {
        self.sync_reduce();
        let acc = self.read_sum();
        self.sync();
        acc
    }

    /// Fold all reduction slots in slot-index order **without**
    /// synchronizing. For callers that weave the reduction into an
    /// existing barrier schedule instead of paying `sync_sum`'s two extra
    /// syncs (the stencil pool's in-loop residual does this: the two
    /// barriers of the halo-exchange protocol already bracket the fold).
    /// The caller must guarantee — with its own `sync` calls — that every
    /// `put` of the round happened before the fold and that no slot is
    /// rewritten until every reader is done; `sync_sum` is exactly
    /// `sync(); read_sum(); sync()`.
    pub fn read_sum(&self) -> f64 {
        self.read_sum_range(0, self.slots.len())
    }

    /// Fold reduction slots `[lo, hi)` in slot-index order without
    /// synchronizing — the multi-dot variant of [`GridBarrier::read_sum`].
    /// Callers that fold several logically distinct sums through one
    /// barrier generation (the pipelined CG pool folds γ, δ and r·r out
    /// of one `sync_reduce`) lay them out as disjoint slot ranges and
    /// fold each range separately; the same `put`-before-fold protocol
    /// as `read_sum` applies per range.
    pub fn read_sum_range(&self, lo: usize, hi: usize) -> f64 {
        let mut acc = 0.0;
        for s in &self.slots[lo..hi] {
            acc += f64::from_bits(s.load(Ordering::Acquire));
        }
        acc
    }

    /// Single-contribution convenience: publish `value` into `slot` (the
    /// caller's participant index) and reduce.
    pub fn sync_sum_at(&self, slot: usize, value: f64) -> f64 {
        self.put(slot, value);
        self.sync_sum()
    }

    /// Total time threads spent blocked at the barrier (sum over threads).
    pub fn total_wait(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.wait_ns.load(Ordering::Relaxed))
    }
}

/// Serialized stderr-style progress log shared by persistent threads
/// (ordinary printing interleaves; solver code must stay lock-free, so
/// only coordinator-level events go through this).
#[derive(Default)]
pub struct EventLog {
    events: Mutex<Vec<String>>,
}

impl EventLog {
    pub fn push(&self, msg: impl Into<String>) {
        self.events.lock().unwrap().push(msg.into());
    }

    pub fn drain(&self) -> Vec<String> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn barrier_synchronizes_counters() {
        // Each thread increments a shared epoch counter only after sync;
        // with a correct barrier no thread can run ahead.
        let n = 4;
        let steps = 50;
        let barrier = Arc::new(GridBarrier::new(n));
        let epoch = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let b = barrier.clone();
                let e = epoch.clone();
                std::thread::spawn(move || {
                    for step in 0..steps {
                        // everyone sees epoch == step * n threads' worth
                        let seen = e.load(Ordering::SeqCst);
                        assert!(seen >= (step as u64) * n as u64);
                        e.fetch_add(1, Ordering::SeqCst);
                        b.sync();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(epoch.load(Ordering::SeqCst), (n * steps) as u64);
        assert_eq!(barrier.generations(), steps as u64);
    }

    #[test]
    fn sync_sum_is_deterministic_regardless_of_arrival_order() {
        // order-sensitive addends: reassociating the fold changes the
        // rounded result, so bit-equality proves the fold order is fixed
        let vals = [1.0e16, -1.0, 3.5e-3, 7.25];
        let expect: f64 = vals.iter().sum(); // left-to-right, 0.0 start
        for round in 0..4u64 {
            let b = Arc::new(GridBarrier::new(vals.len()));
            let handles: Vec<_> = (0..vals.len())
                .map(|i| {
                    let b = b.clone();
                    std::thread::spawn(move || {
                        // stagger arrivals differently every round
                        let ms = (i as u64 + round) % vals.len() as u64;
                        std::thread::sleep(std::time::Duration::from_millis(ms * 3));
                        b.sync_sum_at(i, vals[i])
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap().to_bits(), expect.to_bits());
            }
        }
    }

    #[test]
    fn sync_sum_slots_are_reusable_back_to_back() {
        let n = 3;
        let rounds = 20;
        let b = Arc::new(GridBarrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || {
                    (0..rounds)
                        .map(|k| b.sync_sum_at(i, (i + k * n) as f64))
                        .collect::<Vec<f64>>()
                })
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            for (k, g) in got.into_iter().enumerate() {
                // round k sums k*n .. k*n + n-1
                let want: f64 = (0..n).map(|i| (i + k * n) as f64).sum();
                assert_eq!(g, want, "round {k}");
            }
        }
    }

    #[test]
    fn block_width_reduction_is_invariant_to_participant_count() {
        // the pooled-CG pattern: 5 logical blocks, each with a fixed
        // partial; any worker count must fold to the same bits
        let parts = [0.1, 1.0e15, -3.0, 2.5e-7, 11.0];
        let mut results = Vec::new();
        for workers in [1usize, 2, 5] {
            let b = Arc::new(GridBarrier::with_reduction(workers, parts.len()));
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let b = b.clone();
                    std::thread::spawn(move || {
                        let lo = parts.len() * w / workers;
                        let hi = parts.len() * (w + 1) / workers;
                        for k in lo..hi {
                            b.put(k, parts[k]);
                        }
                        b.sync_sum()
                    })
                })
                .collect();
            let vals: Vec<u64> =
                handles.into_iter().map(|h| h.join().unwrap().to_bits()).collect();
            assert!(vals.windows(2).all(|w| w[0] == w[1]));
            results.push(vals[0]);
        }
        assert!(results.windows(2).all(|w| w[0] == w[1]), "thread-count variant");
        let serial: f64 = parts.iter().sum();
        assert_eq!(results[0], serial.to_bits());
    }

    #[test]
    fn read_sum_folds_in_slot_order_without_syncing() {
        // single participant: put + read_sum must behave exactly like the
        // fold inside sync_sum (left-to-right, 0.0 start), with no barrier
        let vals = [1.0e16, -1.0, 3.5e-3, 7.25];
        let b = GridBarrier::with_reduction(1, vals.len());
        for (i, v) in vals.iter().enumerate() {
            b.put(i, *v);
        }
        let expect: f64 = vals.iter().sum();
        assert_eq!(b.read_sum().to_bits(), expect.to_bits());
        // slots untouched: reading again folds the same bits
        assert_eq!(b.read_sum().to_bits(), expect.to_bits());
        assert_eq!(b.generations(), 0, "read_sum must not sync");
    }

    #[test]
    fn event_log_collects() {
        let log = EventLog::default();
        log.push("a");
        log.push("b");
        assert_eq!(log.drain(), vec!["a".to_string(), "b".to_string()]);
        assert!(log.drain().is_empty());
    }
}
