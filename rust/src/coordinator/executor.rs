//! Execution-model drivers: the heart of the PERKS reproduction.
//!
//! A solver can be advanced under three execution models (DESIGN.md §2):
//!
//! * `HostLoop` — one kernel launch per time step with a full host<->device
//!   round trip of the state in between: the traditional model of Fig 3
//!   (left), where the implicit barrier is the kernel relaunch and all
//!   state traffic goes through "global memory" (host buffers here).
//! * `HostLoopResident` — one launch per step but the state stays in
//!   device buffers (chained via `execute_b`): isolates launch/barrier
//!   overhead from state traffic. This is the *fair* non-PERKS baseline.
//! * `Persistent` — the PERKS model: k time steps fused into a single
//!   executable whose in-kernel loop keeps the state on-chip (VMEM); one
//!   launch advances k steps.
//!
//! All three produce bit-identical states for the same step count (tested),
//! so the models are interchangeable in correctness and differ only in
//! where the inter-step traffic goes — exactly the paper's claim.
//!
//! A fourth, CG-only model — `Pipelined` — lives in the session layer
//! ([`crate::cg::pipeline`]): the pipelined/fused CG formulation with one
//! grid-barrier reduction per iteration. The PJRT drivers below reject it
//! (no pipelined artifact family exists), but the variant is defined here
//! because `ExecMode` is the crate-wide execution-model vocabulary.
//!
//! The drivers here are the PJRT *engine*; the supported public entrypoint
//! is [`crate::session::SessionBuilder`], which wraps them behind the
//! backend-agnostic [`crate::session::Solver`] trait.

use std::rc::Rc;

use crate::error::{Error, Result};
use crate::runtime::{Executable, HostTensor, Runtime};

/// Which execution model to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    HostLoop,
    HostLoopResident,
    Persistent,
    /// Pipelined CG (CG-only): the persistent model with the fused
    /// Ghysels–Vanroose recurrences — one grid-barrier reduction per
    /// iteration instead of two. Stencil drivers reject it.
    Pipelined,
}

impl ExecMode {
    pub fn all() -> [ExecMode; 4] {
        [
            ExecMode::HostLoop,
            ExecMode::HostLoopResident,
            ExecMode::Persistent,
            ExecMode::Pipelined,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::HostLoop => "host-loop",
            ExecMode::HostLoopResident => "host-loop-resident",
            ExecMode::Persistent => "persistent (PERKS)",
            ExecMode::Pipelined => "pipelined",
        }
    }

    /// Stable machine-readable spelling — the `"mode"` key of every
    /// `BENCH_*.json` artifact, matched literally by `bench_check`.
    /// [`ExecMode::parse`] round-trips every value; [`ExecMode::name`] is
    /// the human display form and may carry annotations.
    pub fn key(self) -> &'static str {
        match self {
            ExecMode::HostLoop => "host-loop",
            ExecMode::HostLoopResident => "host-loop-resident",
            ExecMode::Persistent => "persistent",
            ExecMode::Pipelined => "pipelined",
        }
    }

    /// Parse a CLI spelling of a mode. Accepts the short aliases used by
    /// the `perks` binary (`resident`, `perks`, `pipe`).
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "host-loop" => Some(ExecMode::HostLoop),
            "resident" | "host-loop-resident" => Some(ExecMode::HostLoopResident),
            "persistent" | "perks" => Some(ExecMode::Persistent),
            "pipelined" | "pipe" => Some(ExecMode::Pipelined),
            _ => None,
        }
    }
}

/// Result of advancing a solver.
#[derive(Debug)]
pub struct RunReport {
    pub mode: ExecMode,
    pub steps: usize,
    pub wall_seconds: f64,
    pub invocations: u64,
    pub host_bytes: u64,
    pub state: Vec<HostTensor>,
}

impl RunReport {
    /// Cell updates per second (the paper's stencil FOM), given the
    /// interior cell count of the domain. The wall time is clamped to a
    /// measurable epsilon so very fast runs (a 0-duration `Instant` delta)
    /// report a finite rate instead of `inf`/`NaN`.
    pub fn cells_per_sec(&self, interior_cells: usize) -> f64 {
        crate::util::stats::finite_rate(
            interior_cells as f64 * self.steps as f64,
            self.wall_seconds,
        )
    }
}

/// Driver for iterative stencil artifacts.
pub struct StencilDriver {
    step: Rc<Executable>,
    step_raw: Option<Rc<Executable>>,
    perks: Rc<Executable>,
    perks_raw: Option<Rc<Executable>>,
    pub bench: String,
    pub interior: Vec<usize>,
    pub fused_steps: usize,
}

impl StencilDriver {
    /// Look up the artifact family for `bench`/`interior`/`dtype` in the
    /// runtime manifest. `interior` like "128x128", dtype "f32"|"f64".
    pub(crate) fn from_runtime(
        rt: &Runtime,
        bench: &str,
        interior: &str,
        dtype: &str,
    ) -> Result<Self> {
        let base = format!("stencil_{bench}_{interior}_{dtype}");
        let mut step = None;
        let mut step_raw = None;
        let mut perks = None;
        let mut perks_raw = None;
        let mut fused = 0usize;
        for a in &rt.manifest.artifacts {
            if !a.name.starts_with(&base) {
                continue;
            }
            let suffix = &a.name[base.len()..];
            match a.kind.as_str() {
                "stencil_step" if suffix == "_step" => step = Some(rt.load(&a.name)?),
                "stencil_step" if suffix == "_step_raw" => step_raw = Some(rt.load(&a.name)?),
                "stencil_perks" if !suffix.ends_with("_raw") => {
                    fused = a.int("steps")?;
                    perks = Some(rt.load(&a.name)?);
                }
                "stencil_perks" => perks_raw = Some(rt.load(&a.name)?),
                _ => {}
            }
        }
        let step = step.ok_or_else(|| Error::Manifest(format!("no step artifact for {base}")))?;
        let perks =
            perks.ok_or_else(|| Error::Manifest(format!("no perks artifact for {base}")))?;
        let interior_dims = interior
            .split('x')
            .map(|d| d.parse::<usize>().map_err(|_| Error::invalid("bad interior")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            step,
            step_raw,
            perks,
            perks_raw,
            bench: bench.to_string(),
            interior: interior_dims,
            fused_steps: fused,
        })
    }

    pub fn interior_cells(&self) -> usize {
        self.interior.iter().product()
    }

    /// Advance the padded domain `x0` by `steps` under the given model.
    pub fn run(&self, mode: ExecMode, x0: &HostTensor, steps: usize) -> Result<RunReport> {
        match mode {
            ExecMode::HostLoop => self.run_host_loop(x0, steps),
            ExecMode::HostLoopResident => self.run_host_loop_resident(x0, steps),
            ExecMode::Persistent => self.run_persistent(x0, steps),
            ExecMode::Pipelined => Err(Error::invalid(
                "pipelined is a CG-only execution model; stencils have no dot-product pipeline",
            )),
        }
    }

    fn run_host_loop(&self, x0: &HostTensor, steps: usize) -> Result<RunReport> {
        let t0 = std::time::Instant::now();
        let mut state = x0.clone();
        let mut host_bytes = 0u64;
        for _ in 0..steps {
            let out = self.step.run(std::slice::from_ref(&state))?;
            state = out.into_iter().next().unwrap();
            host_bytes += 2 * state.bytes() as u64; // up + down each step
        }
        Ok(RunReport {
            mode: ExecMode::HostLoop,
            steps,
            wall_seconds: t0.elapsed().as_secs_f64(),
            invocations: steps as u64,
            host_bytes,
            state: vec![state],
        })
    }

    fn run_host_loop_resident(&self, x0: &HostTensor, steps: usize) -> Result<RunReport> {
        let raw = self.step_raw.as_ref().ok_or_else(|| {
            Error::Manifest(format!("no raw step artifact for {}", self.bench))
        })?;
        let t0 = std::time::Instant::now();
        // Seed the chain with one literal upload; thereafter outputs feed
        // inputs as device buffers (no host round trip).
        let lit = x0.to_literal()?;
        let mut bufs = raw.run_literals(&[lit])?;
        for _ in 1..steps {
            let input = bufs.remove(0).remove(0);
            bufs = raw.run_buffers(&[input])?;
        }
        let final_lit = bufs[0][0].to_literal_sync()?;
        let state = HostTensor::from_literal(&final_lit, &raw.meta.outputs[0])?;
        Ok(RunReport {
            mode: ExecMode::HostLoopResident,
            steps,
            wall_seconds: t0.elapsed().as_secs_f64(),
            invocations: steps as u64,
            host_bytes: 2 * x0.bytes() as u64,
            state: vec![state],
        })
    }

    fn run_persistent(&self, x0: &HostTensor, steps: usize) -> Result<RunReport> {
        if steps % self.fused_steps != 0 {
            return Err(Error::invalid(format!(
                "steps {} not a multiple of fused_steps {}",
                steps, self.fused_steps
            )));
        }
        let launches = steps / self.fused_steps;
        let t0 = std::time::Instant::now();
        let (state, invocations) = match (&self.perks_raw, launches) {
            // Chain device buffers between persistent launches when the raw
            // artifact exists; otherwise fall back to host round trips per
            // k-step launch.
            (Some(raw), n) if n > 0 => {
                let lit = x0.to_literal()?;
                let mut bufs = raw.run_literals(&[lit])?;
                for _ in 1..n {
                    let input = bufs.remove(0).remove(0);
                    bufs = raw.run_buffers(&[input])?;
                }
                let final_lit = bufs[0][0].to_literal_sync()?;
                (HostTensor::from_literal(&final_lit, &raw.meta.outputs[0])?, n as u64)
            }
            _ => {
                let mut state = x0.clone();
                for _ in 0..launches {
                    let out = self.perks.run(std::slice::from_ref(&state))?;
                    state = out.into_iter().next().unwrap();
                }
                (state, launches as u64)
            }
        };
        Ok(RunReport {
            mode: ExecMode::Persistent,
            steps,
            wall_seconds: t0.elapsed().as_secs_f64(),
            invocations,
            host_bytes: 2 * x0.bytes() as u64,
            state: vec![state],
        })
    }
}

/// Driver for the conjugate-gradient artifacts.
pub struct CgDriver {
    step: Rc<Executable>,
    perks: Rc<Executable>,
    residual: Rc<Executable>,
    pub n: usize,
    pub nnz: usize,
    pub fused_iters: usize,
}

/// Final state of a CG run.
#[derive(Debug)]
pub struct CgReport {
    pub mode: ExecMode,
    pub iters: usize,
    pub wall_seconds: f64,
    pub invocations: u64,
    pub rr: f64,
    pub x: Vec<f32>,
}

impl CgDriver {
    pub(crate) fn from_runtime(rt: &Runtime, n: usize) -> Result<Self> {
        let step = rt.load(&format!("cg_step_n{n}"))?;
        let nnz = step.meta.int("nnz")?;
        // find the perks artifact for this n (any fused count)
        let perks_meta = rt
            .manifest
            .artifacts
            .iter()
            .find(|a| a.kind == "cg_perks" && a.int("n").ok() == Some(n))
            .ok_or_else(|| Error::Manifest(format!("no cg_perks artifact for n={n}")))?
            .name
            .clone();
        let perks = rt.load(&perks_meta)?;
        let fused_iters = perks.meta.int("iters")?;
        let residual = rt.load(&format!("cg_residual_n{n}"))?;
        Ok(Self { step, perks, residual, n, nnz, fused_iters })
    }

    /// The artifact-shaped initial CG state `[x, r, p, rr]` for a rhs `b`
    /// (x = 0, r = p = b, rr = b·b).
    pub fn initial_state(&self, b: &[f32]) -> Vec<HostTensor> {
        let n = self.n;
        let x = HostTensor::f32(&[n], vec![0.0; n]);
        let r = HostTensor::f32(&[n], b.to_vec());
        let p = r.clone();
        let rr0: f32 = b.iter().map(|v| v * v).sum();
        vec![x, r, p, HostTensor::f32(&[1], vec![rr0])]
    }

    /// Advance an existing CG state by `iters` iterations, returning the
    /// new state and the number of executable invocations. The matrix
    /// tensors are cloned exactly once (outside the chunk loop) and the
    /// state tensors are moved between launches, so the hot loop performs
    /// no host-side copies.
    pub fn advance(
        &self,
        mode: ExecMode,
        data: &HostTensor,
        cols: &HostTensor,
        rows: &HostTensor,
        state: Vec<HostTensor>,
        iters: usize,
    ) -> Result<(Vec<HostTensor>, u64)> {
        if state.len() != 4 {
            return Err(Error::invalid(format!(
                "CG state must be [x, r, p, rr], got {} tensors",
                state.len()
            )));
        }
        let exe = match mode {
            ExecMode::Persistent => &self.perks,
            ExecMode::Pipelined => {
                // no pipelined artifact family exists; the CPU backend is
                // the pipelined engine ([`crate::cg::pipeline`])
                return Err(Error::invalid(
                    "pipelined CG is not available on the PJRT backend",
                ));
            }
            _ => &self.step,
        };
        let chunk = match mode {
            ExecMode::Persistent => self.fused_iters,
            _ => 1,
        };
        if iters % chunk != 0 {
            return Err(Error::invalid(format!("iters {iters} not a multiple of {chunk}")));
        }
        let mut inputs = Vec::with_capacity(7);
        inputs.push(data.clone());
        inputs.push(cols.clone());
        inputs.push(rows.clone());
        inputs.extend(state);
        let mut invocations = 0u64;
        for _ in 0..iters / chunk {
            let out = exe.run(&inputs)?;
            inputs.truncate(3);
            inputs.extend(out);
            invocations += 1;
        }
        Ok((inputs.split_off(3), invocations))
    }

    /// Solve Ax=b for `iters` iterations under the given model. The matrix
    /// is passed in COO-with-row-ids form matching the artifact signature.
    pub fn run(
        &self,
        mode: ExecMode,
        data: &HostTensor,
        cols: &HostTensor,
        rows: &HostTensor,
        b: &[f32],
        iters: usize,
    ) -> Result<CgReport> {
        let t0 = std::time::Instant::now();
        let state = self.initial_state(b);
        let (state, invocations) = self.advance(mode, data, cols, rows, state, iters)?;
        let wall = t0.elapsed().as_secs_f64();
        let rr = state[3].as_f32()?[0] as f64;
        let x = state[0].as_f32()?.to_vec();
        Ok(CgReport { mode, iters, wall_seconds: wall, invocations, rr, x })
    }

    /// On-device residual check ||b - Ax||^2.
    pub fn residual(
        &self,
        data: &HostTensor,
        cols: &HostTensor,
        rows: &HostTensor,
        x: &[f32],
        b: &[f32],
    ) -> Result<f64> {
        let out = self.residual.run(&[
            data.clone(),
            cols.clone(),
            rows.clone(),
            HostTensor::f32(&[self.n], x.to_vec()),
            HostTensor::f32(&[self.n], b.to_vec()),
        ])?;
        Ok(out[0].as_f32()?[0] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::ExecMode;

    /// `key()` is the BENCH-json vocabulary: stable, annotation-free, and
    /// round-tripped by `parse` for every mode (unlike `name()`, whose
    /// display form may carry annotations like "persistent (PERKS)").
    #[test]
    fn mode_keys_round_trip_and_stay_annotation_free() {
        for mode in ExecMode::all() {
            assert_eq!(ExecMode::parse(mode.key()), Some(mode));
            assert!(!mode.key().contains(' '), "json key {:?} must be bare", mode.key());
        }
        assert_eq!(ExecMode::parse("pipe"), Some(ExecMode::Pipelined));
        assert_eq!(ExecMode::parse("perks"), Some(ExecMode::Persistent));
    }
}
