//! Profile-guided caching-policy advisor (paper §III-B-2: "this step can
//! be automated by using a dedicated profile-guided utility ... to aid the
//! user in swiftly identifying an ideal caching policy, based on the
//! access patterns and frequency of access of data arrays in the solver").
//!
//! Solvers record per-array access counters into an `AccessProfile`; the
//! advisor ranks arrays by traffic-saved-per-cached-byte and emits a
//! `CachePlan` through the §III-B planner, plus a human-readable report.

use std::collections::BTreeMap;

use crate::coordinator::caching::{self, CacheLocation, CachePlan, CacheableArray};

/// Per-array access counters accumulated over some profiled window.
#[derive(Clone, Debug, Default)]
pub struct ArrayStats {
    pub bytes: f64,
    pub loads: u64,
    pub stores: u64,
    /// Steps/iterations observed, to normalize to per-step rates.
    pub steps: u64,
}

impl ArrayStats {
    /// Loads per byte per step.
    pub fn load_rate(&self) -> f64 {
        if self.bytes == 0.0 || self.steps == 0 {
            return 0.0;
        }
        self.loads as f64 / self.bytes / self.steps as f64
    }

    pub fn store_rate(&self) -> f64 {
        if self.bytes == 0.0 || self.steps == 0 {
            return 0.0;
        }
        self.stores as f64 / self.bytes / self.steps as f64
    }
}

/// The profile: a map from array name to counters.
#[derive(Clone, Debug, Default)]
pub struct AccessProfile {
    arrays: BTreeMap<String, ArrayStats>,
    steps: u64,
}

impl AccessProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare an array and its size in bytes.
    pub fn declare(&mut self, name: &str, bytes: f64) {
        self.arrays.entry(name.into()).or_default().bytes = bytes;
    }

    /// Record `n` bytes loaded from `name`.
    pub fn load(&mut self, name: &str, n: u64) {
        self.arrays.entry(name.into()).or_default().loads += n;
    }

    /// Record `n` bytes stored to `name`.
    pub fn store(&mut self, name: &str, n: u64) {
        self.arrays.entry(name.into()).or_default().stores += n;
    }

    /// Mark the end of one time step / iteration.
    pub fn step(&mut self) {
        self.steps += 1;
    }

    pub fn finish(mut self) -> Self {
        for s in self.arrays.values_mut() {
            s.steps = self.steps;
        }
        self
    }

    /// Convert to planner inputs, ranked by density.
    pub fn cacheable_arrays(&self) -> Vec<CacheableArray> {
        let mut v: Vec<CacheableArray> = self
            .arrays
            .iter()
            .map(|(name, s)| CacheableArray::new(name, s.bytes, s.load_rate(), s.store_rate()))
            .collect();
        v.sort_by(|a, b| b.density().partial_cmp(&a.density()).unwrap());
        v
    }

    /// Produce a recommended plan for the given capacities.
    pub fn recommend(&self, sm_capacity: f64, reg_capacity: f64) -> CachePlan {
        caching::plan(CacheLocation::Both, &self.cacheable_arrays(), sm_capacity, reg_capacity)
    }

    /// Human-readable advisory report.
    pub fn report(&self, sm_capacity: f64, reg_capacity: f64) -> String {
        let arrays = self.cacheable_arrays();
        let plan = self.recommend(sm_capacity, reg_capacity);
        let mut out = String::from("profile-guided caching advisory\n");
        out.push_str(&format!(
            "capacity: {} smem + {} regs\n",
            crate::util::fmt::bytes(sm_capacity),
            crate::util::fmt::bytes(reg_capacity)
        ));
        for a in &arrays {
            let al = plan.allocation(&a.name).unwrap();
            out.push_str(&format!(
                "  {:<12} {:>12}  density {:.2}/step  -> cache {:.0}% ({} sm, {} reg)\n",
                a.name,
                crate::util::fmt::bytes(a.bytes),
                a.density(),
                al.fraction() * 100.0,
                crate::util::fmt::bytes(al.cached_bytes_sm),
                crate::util::fmt::bytes(al.cached_bytes_reg),
            ));
        }
        out
    }
}

/// Profile one CG iteration's array accesses (the paper's own example:
/// r sees 3 loads + 1 store per element, A one load).
pub fn profile_cg(n: usize, nnz: usize, elem: usize, iters: u64) -> AccessProfile {
    let mut p = AccessProfile::new();
    p.declare("A", (nnz * (elem + 4)) as f64);
    p.declare("r", (n * elem) as f64);
    p.declare("p", (n * elem) as f64);
    p.declare("x", (n * elem) as f64);
    for _ in 0..iters {
        p.load("A", (nnz * (elem + 4)) as u64);
        p.load("r", 3 * (n * elem) as u64);
        p.store("r", (n * elem) as u64);
        p.load("p", 3 * (n * elem) as u64);
        p.store("p", (n * elem) as u64);
        p.load("x", (n * elem) as u64);
        p.store("x", (n * elem) as u64);
        p.step();
    }
    p.finish()
}

/// Profile a stencil's tiers (interior/boundary/halo), matching
/// `caching::stencil_tiers`.
pub fn profile_stencil(interior_bytes: u64, boundary_bytes: u64, steps: u64) -> AccessProfile {
    let mut p = AccessProfile::new();
    p.declare("interior", interior_bytes as f64);
    p.declare("boundary", boundary_bytes as f64);
    for _ in 0..steps {
        p.load("interior", interior_bytes);
        p.store("interior", interior_bytes);
        p.load("boundary", boundary_bytes);
        p.step();
    }
    p.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cg_profile_ranks_r_above_a() {
        // the paper's §III-B-2 conclusion: r > A
        let p = profile_cg(1000, 10_000, 4, 5);
        let arrays = p.cacheable_arrays();
        let r_pos = arrays.iter().position(|a| a.name == "r").unwrap();
        let a_pos = arrays.iter().position(|a| a.name == "A").unwrap();
        assert!(r_pos < a_pos, "r must rank above A: {arrays:?}");
        // r density = 4 (3 loads + 1 store), A density = 1
        assert!((arrays[r_pos].density() - 4.0).abs() < 1e-9);
        assert!((arrays[a_pos].density() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn stencil_profile_ranks_interior_above_boundary() {
        let p = profile_stencil(10_000, 1_000, 3);
        let arrays = p.cacheable_arrays();
        assert_eq!(arrays[0].name, "interior");
        assert!((arrays[0].density() - 2.0).abs() < 1e-9);
        assert!((arrays[1].density() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recommendation_respects_capacity_and_priority() {
        let p = profile_cg(1000, 100_000, 4, 2);
        // capacity fits exactly the three hot vectors (r, p at density 4,
        // then x at 2): 3 * 4000 bytes
        let plan = p.recommend(8000.0, 4000.0);
        assert!(plan.cached_bytes() <= 12_000.0 + 1e-9);
        let r = plan.allocation("r").unwrap();
        let pv = plan.allocation("p").unwrap();
        assert!((r.fraction() - 1.0).abs() < 1e-9);
        assert!((pv.fraction() - 1.0).abs() < 1e-9);
        let a = plan.allocation("A").unwrap();
        assert_eq!(a.cached_bytes(), 0.0, "A must lose to the vectors");
    }

    #[test]
    fn report_is_readable() {
        let p = profile_cg(100, 1000, 4, 1);
        let rep = p.report(4096.0, 1024.0);
        assert!(rep.contains("advisory"));
        assert!(rep.contains('A') && rep.contains('r'));
    }

    #[test]
    fn empty_profile_recommends_nothing() {
        let p = AccessProfile::new().finish();
        let plan = p.recommend(1e6, 1e6);
        assert_eq!(plan.cached_bytes(), 0.0);
    }
}
