//! Multi-device PERKS (paper §III-A, "PERKS in Distributed Computing").
//!
//! The domain is row-partitioned into shards, one executable instance per
//! "device" (here: separate PJRT executions over shard-sized artifacts),
//! with the coordinator performing the halo exchange between time steps —
//! the role MPI plays in the paper's distributed setting.
//!
//! Two schedules:
//!
//! * `step_exchange`  — exchange every step (the classic distributed
//!   host-loop: correct for any stencil radius);
//! * `fused_exchange` — advance each shard k steps with the *persistent*
//!   shard executable between exchanges. This trades halo staleness for
//!   fused execution exactly like overlapped temporal blocking would, so
//!   it is only exact when the halo depth covers k*radius; with depth =
//!   radius it is an *approximation* controlled by `k` — the coordinator
//!   therefore only offers it for k == 1 unless the caller opts into the
//!   wider-halo artifacts. (We keep the API honest: `fused_exchange`
//!   validates k == 1 for radius-deep halos.)

use crate::error::{Error, Result};
use crate::runtime::{HostTensor, Runtime};

/// A row-sharded 2D stencil domain distributed over shard executables.
pub struct MultiDevStencil {
    step_name: String,
    /// interior rows per shard, interior cols
    pub shard_rows: usize,
    pub cols: usize,
    pub radius: usize,
    pub shards: usize,
}

impl MultiDevStencil {
    /// `interior` is the per-shard interior ("64x128"); the global domain
    /// stacks `shards` of them vertically.
    pub fn new(rt: &Runtime, bench: &str, interior: &str, dtype: &str, shards: usize) -> Result<Self> {
        if shards < 2 {
            return Err(Error::invalid("need >= 2 shards"));
        }
        let step_name = format!("stencil_{bench}_{interior}_{dtype}_step");
        let meta = rt.manifest.get(&step_name)?;
        let radius = meta.int("radius")?;
        let dims: Vec<usize> = interior
            .split('x')
            .map(|d| d.parse().map_err(|_| Error::invalid("bad interior")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { step_name, shard_rows: dims[0], cols: dims[1], radius, shards })
    }

    pub fn global_rows(&self) -> usize {
        self.shard_rows * self.shards
    }

    /// Split a global padded f32 domain (rows+2r, cols+2r) into per-shard
    /// padded arrays, seeding each shard's inter-shard halo from its
    /// neighbour's interior.
    fn scatter(&self, global: &[f32]) -> Vec<Vec<f32>> {
        let r = self.radius;
        let pcols = self.cols + 2 * r;
        let prows_shard = self.shard_rows + 2 * r;
        (0..self.shards)
            .map(|s| {
                let mut shard = vec![0.0f32; prows_shard * pcols];
                // global row index of this shard's first padded row
                let g0 = s * self.shard_rows; // padded-global row g0..g0+prows
                for lr in 0..prows_shard {
                    let gr = g0 + lr;
                    let src = &global[gr * pcols..(gr + 1) * pcols];
                    shard[lr * pcols..(lr + 1) * pcols].copy_from_slice(src);
                }
                shard
            })
            .collect()
    }

    /// Reassemble the global padded domain from shard interiors (+ outer
    /// halos from the edge shards).
    fn gather(&self, shards: &[Vec<f32>]) -> Vec<f32> {
        let r = self.radius;
        let pcols = self.cols + 2 * r;
        let prows_global = self.global_rows() + 2 * r;
        let mut global = vec![0.0f32; prows_global * pcols];
        // top halo from shard 0, bottom halo from last shard
        for lr in 0..r {
            global[lr * pcols..(lr + 1) * pcols]
                .copy_from_slice(&shards[0][lr * pcols..(lr + 1) * pcols]);
        }
        let last = &shards[self.shards - 1];
        let lr_base = r + self.shard_rows;
        for i in 0..r {
            let gr = r + self.global_rows() + i;
            let lr = lr_base + i;
            global[gr * pcols..(gr + 1) * pcols]
                .copy_from_slice(&last[lr * pcols..(lr + 1) * pcols]);
        }
        for (s, shard) in shards.iter().enumerate() {
            for row in 0..self.shard_rows {
                let gr = r + s * self.shard_rows + row;
                let lr = r + row;
                global[gr * pcols..(gr + 1) * pcols]
                    .copy_from_slice(&shard[lr * pcols..(lr + 1) * pcols]);
            }
        }
        global
    }

    /// Halo exchange: copy each shard's boundary interior rows into the
    /// neighbour's halo rows. Returns bytes exchanged.
    fn exchange(&self, shards: &mut [Vec<f32>]) -> u64 {
        let r = self.radius;
        let pcols = self.cols + 2 * r;
        let mut moved = 0u64;
        for s in 0..self.shards - 1 {
            // bottom interior rows of s -> top halo of s+1
            for i in 0..r {
                let src_row = r + self.shard_rows - r + i;
                let dst_row = i;
                let (a, b) = shards.split_at_mut(s + 1);
                b[0][dst_row * pcols..(dst_row + 1) * pcols]
                    .copy_from_slice(&a[s][src_row * pcols..(src_row + 1) * pcols]);
                // top interior rows of s+1 -> bottom halo of s
                let src2 = r + i;
                let dst2 = r + self.shard_rows + i;
                a[s][dst2 * pcols..(dst2 + 1) * pcols]
                    .copy_from_slice(&b[0][src2 * pcols..(src2 + 1) * pcols]);
                moved += 2 * (pcols * 4) as u64;
            }
        }
        moved
    }

    /// Advance the global padded domain `steps` steps with an exchange
    /// after every step. Returns (global padded result, bytes exchanged).
    pub fn step_exchange(
        &self,
        rt: &Runtime,
        global: &[f32],
        steps: usize,
    ) -> Result<(Vec<f32>, u64)> {
        let r = self.radius;
        let pcols = self.cols + 2 * r;
        let prows_shard = self.shard_rows + 2 * r;
        let expected = (self.global_rows() + 2 * r) * pcols;
        if global.len() != expected {
            return Err(Error::Shape(format!(
                "global domain has {} elements, expected {expected}",
                global.len()
            )));
        }
        let exe = rt.load(&self.step_name)?;
        let mut shards = self.scatter(global);
        let mut exchanged = 0u64;
        for _ in 0..steps {
            for shard in shards.iter_mut() {
                let input = HostTensor::f32(&[prows_shard, pcols], shard.clone());
                let out = exe.run(std::slice::from_ref(&input))?;
                *shard = out.into_iter().next().unwrap().as_f32()?.to_vec();
            }
            exchanged += self.exchange(&mut shards);
        }
        Ok((self.gather(&shards), exchanged))
    }
}

#[cfg(test)]
mod tests {
    // exercised end-to-end in rust/tests/integration.rs (needs artifacts);
    // the pure scatter/gather/exchange logic is tested here via a stub
    // geometry without touching PJRT.
    use super::*;

    fn stub() -> MultiDevStencil {
        MultiDevStencil {
            step_name: "unused".into(),
            shard_rows: 2,
            cols: 3,
            radius: 1,
            shards: 2,
        }
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let m = stub();
        let pcols = 5;
        let prows = 6; // 4 interior + 2 halo
        let global: Vec<f32> = (0..(prows * pcols) as i32).map(|v| v as f32).collect();
        let shards = m.scatter(&global);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len(), 4 * pcols);
        let back = m.gather(&shards);
        assert_eq!(back, global);
    }

    #[test]
    fn exchange_moves_boundary_rows() {
        let m = stub();
        let pcols = 5;
        let mut shards = m.scatter(
            &(0..30i32).map(|v| v as f32).collect::<Vec<f32>>(),
        );
        // poison the halos, then exchange must repair them from neighbours
        for s in shards.iter_mut() {
            for v in s.iter_mut().take(pcols) {
                *v = -1.0;
            }
        }
        let moved = m.exchange(&mut shards);
        assert_eq!(moved, 2 * (pcols * 4) as u64);
        // shard 1's top halo == shard 0's last interior row (global row 2)
        let want: Vec<f32> = (10..15).map(|v| v as f32).collect();
        assert_eq!(&shards[1][..pcols], &want[..]);
    }
}
