//! Caching policy engine (paper §III-B).
//!
//! Given the on-chip capacity freed by running at minimum occupancy and a
//! description of the solver's arrays (how many bytes each loads/stores per
//! time step), decide *what* to cache and *where* (shared memory analog,
//! registers analog, or both). The paper's rules implemented here:
//!
//! * priority: data with no inter-TB dependency (interior) > data with
//!   inter-TB dependency (TB boundary) > halo (never cached);
//! * CG: residual vector r (3 loads + 1 store per step per element) before
//!   matrix A (1 load) — i.e., rank arrays by traffic saved per cached byte;
//! * greedy fill: arrays are divisible, so fractional caching is allowed
//!   (the paper caches "a subset of the domain").

/// Where cached data may live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheLocation {
    /// No explicit caching: rely on L2 hits (paper policy "IMP").
    Implicit,
    /// Shared-memory only ("SM").
    SharedOnly,
    /// Register-file only ("REG").
    RegOnly,
    /// Both ("BTH"/"MIX").
    Both,
}

impl CacheLocation {
    pub fn all() -> [CacheLocation; 4] {
        [
            CacheLocation::Implicit,
            CacheLocation::SharedOnly,
            CacheLocation::RegOnly,
            CacheLocation::Both,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            CacheLocation::Implicit => "IMP",
            CacheLocation::SharedOnly => "SM",
            CacheLocation::RegOnly => "REG",
            CacheLocation::Both => "BTH",
        }
    }
}

/// One cacheable array (or domain tier) of a solver.
#[derive(Clone, Debug)]
pub struct CacheableArray {
    pub name: String,
    /// Total size in bytes.
    pub bytes: f64,
    /// Global-memory bytes *loaded* per time step per byte of array if NOT
    /// cached (e.g. 1.0 for a stencil domain; 3.0 for CG's r).
    pub loads_per_step: f64,
    /// Global-memory bytes *stored* per step per byte if not cached.
    pub stores_per_step: f64,
}

impl CacheableArray {
    pub fn new(name: &str, bytes: f64, loads: f64, stores: f64) -> Self {
        Self { name: name.into(), bytes, loads_per_step: loads, stores_per_step: stores }
    }

    /// Traffic saved per cached byte per time step: caching eliminates both
    /// the loads and the stores of the covered bytes.
    pub fn density(&self) -> f64 {
        self.loads_per_step + self.stores_per_step
    }
}

/// A planned allocation for one array.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub name: String,
    pub cached_bytes_sm: f64,
    pub cached_bytes_reg: f64,
    pub total_bytes: f64,
}

impl Allocation {
    pub fn cached_bytes(&self) -> f64 {
        self.cached_bytes_sm + self.cached_bytes_reg
    }

    pub fn fraction(&self) -> f64 {
        if self.total_bytes == 0.0 {
            0.0
        } else {
            self.cached_bytes() / self.total_bytes
        }
    }
}

/// The cache plan for a solver configuration.
#[derive(Clone, Debug)]
pub struct CachePlan {
    pub location: CacheLocation,
    pub allocations: Vec<Allocation>,
    pub sm_capacity: f64,
    pub reg_capacity: f64,
}

impl CachePlan {
    pub fn cached_bytes(&self) -> f64 {
        self.allocations.iter().map(|a| a.cached_bytes()).sum()
    }

    pub fn cached_bytes_sm(&self) -> f64 {
        self.allocations.iter().map(|a| a.cached_bytes_sm).sum()
    }

    pub fn cached_bytes_reg(&self) -> f64 {
        self.allocations.iter().map(|a| a.cached_bytes_reg).sum()
    }

    /// Traffic (bytes to global memory) saved per time step by this plan.
    pub fn saved_bytes_per_step(&self, arrays: &[CacheableArray]) -> f64 {
        self.allocations
            .iter()
            .map(|al| {
                let arr = arrays.iter().find(|a| a.name == al.name).expect("array");
                al.cached_bytes() * arr.density()
            })
            .sum()
    }

    pub fn allocation(&self, name: &str) -> Option<&Allocation> {
        self.allocations.iter().find(|a| a.name == name)
    }
}

/// Plan caching greedily by traffic density (paper §III-B-2).
///
/// `sm_capacity` / `reg_capacity` are the bytes freed for caching at the
/// chosen occupancy. Arrays are sorted by `density()` descending and filled
/// fractionally; shared memory is filled before registers for `Both`
/// (registers carry the spill risk the paper warns about in §IV-E).
pub fn plan(
    location: CacheLocation,
    arrays: &[CacheableArray],
    sm_capacity: f64,
    reg_capacity: f64,
) -> CachePlan {
    let (mut sm_free, mut reg_free) = match location {
        CacheLocation::Implicit => (0.0, 0.0),
        CacheLocation::SharedOnly => (sm_capacity, 0.0),
        CacheLocation::RegOnly => (0.0, reg_capacity),
        CacheLocation::Both => (sm_capacity, reg_capacity),
    };
    let mut order: Vec<&CacheableArray> = arrays.iter().collect();
    // stable sort: equal densities keep input order (lets callers encode
    // tie-breaking priorities positionally)
    order.sort_by(|a, b| b.density().partial_cmp(&a.density()).unwrap());

    let mut allocations = Vec::with_capacity(arrays.len());
    for arr in order {
        let mut remaining = arr.bytes;
        let to_sm = remaining.min(sm_free);
        sm_free -= to_sm;
        remaining -= to_sm;
        let to_reg = remaining.min(reg_free);
        reg_free -= to_reg;
        allocations.push(Allocation {
            name: arr.name.clone(),
            cached_bytes_sm: to_sm,
            cached_bytes_reg: to_reg,
            total_bytes: arr.bytes,
        });
    }
    CachePlan { location, allocations, sm_capacity, reg_capacity }
}

/// The paper's stencil domain decomposition into cache tiers (§III-B-2):
/// interior cells (no inter-TB dependency: caching saves 1 load + 1 store),
/// TB-boundary cells (caching saves the load only; the store must still go
/// to global memory for neighbors), halo (never cached).
pub fn stencil_tiers(
    interior_bytes: f64,
    boundary_bytes: f64,
    halo_bytes: f64,
) -> Vec<CacheableArray> {
    vec![
        CacheableArray::new("interior", interior_bytes, 1.0, 1.0),
        CacheableArray::new("tb-boundary", boundary_bytes, 1.0, 0.0),
        // halo: zero density => never prioritized; listed for accounting
        CacheableArray::new("halo", halo_bytes, 0.0, 0.0),
    ]
}

/// The paper's CG arrays (§III-B-2): r has 3 loads + 1 store per iteration,
/// A has 1 load. With equal tie priority, ordering is r > A as in the paper.
pub fn cg_arrays(matrix_bytes: f64, vector_bytes: f64) -> Vec<CacheableArray> {
    vec![
        CacheableArray::new("r", vector_bytes, 3.0, 1.0),
        CacheableArray::new("A", matrix_bytes, 1.0, 0.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn implicit_caches_nothing() {
        let arrays = cg_arrays(1000.0, 100.0);
        let p = plan(CacheLocation::Implicit, &arrays, 500.0, 500.0);
        assert_eq!(p.cached_bytes(), 0.0);
    }

    #[test]
    fn never_exceeds_capacity() {
        let arrays = cg_arrays(1e9, 1e8);
        for loc in CacheLocation::all() {
            let p = plan(loc, &arrays, 1234.0, 567.0);
            assert!(p.cached_bytes_sm() <= 1234.0 + 1e-9);
            assert!(p.cached_bytes_reg() <= 567.0 + 1e-9);
        }
    }

    #[test]
    fn cg_priority_r_before_a() {
        // capacity only fits the vector: r must win (paper: cache r > A)
        let arrays = cg_arrays(1000.0, 100.0);
        let p = plan(CacheLocation::SharedOnly, &arrays, 100.0, 0.0);
        assert_eq!(p.allocation("r").unwrap().cached_bytes(), 100.0);
        assert_eq!(p.allocation("A").unwrap().cached_bytes(), 0.0);
    }

    #[test]
    fn stencil_priority_interior_boundary_halo() {
        let tiers = stencil_tiers(1000.0, 100.0, 50.0);
        let p = plan(CacheLocation::Both, &tiers, 600.0, 500.0);
        // interior fully cached first (density 2), then boundary (density 1)
        assert_eq!(p.allocation("interior").unwrap().cached_bytes(), 1000.0);
        assert_eq!(p.allocation("tb-boundary").unwrap().cached_bytes(), 100.0);
        assert_eq!(p.allocation("halo").unwrap().cached_bytes(), 0.0);
    }

    #[test]
    fn fractional_fill_when_capacity_short() {
        let tiers = stencil_tiers(1000.0, 100.0, 0.0);
        let p = plan(CacheLocation::SharedOnly, &tiers, 300.0, 0.0);
        let i = p.allocation("interior").unwrap();
        assert_eq!(i.cached_bytes(), 300.0);
        assert!((i.fraction() - 0.3).abs() < 1e-12);
        assert_eq!(p.allocation("tb-boundary").unwrap().cached_bytes(), 0.0);
    }

    #[test]
    fn saved_traffic_accounting() {
        let tiers = stencil_tiers(100.0, 0.0, 0.0);
        let p = plan(CacheLocation::RegOnly, &tiers, 0.0, 100.0);
        // interior density = 2 (load+store) => 200 bytes/step saved
        assert_eq!(p.saved_bytes_per_step(&tiers), 200.0);
    }

    #[test]
    fn both_fills_sm_before_reg() {
        let tiers = stencil_tiers(150.0, 0.0, 0.0);
        let p = plan(CacheLocation::Both, &tiers, 100.0, 100.0);
        let i = p.allocation("interior").unwrap();
        assert_eq!(i.cached_bytes_sm, 100.0);
        assert_eq!(i.cached_bytes_reg, 50.0);
    }
}
