//! Fig 6: PERKS speedup for small (fully-cacheable) domains — the strong
//! scaling case — A100 + V100, sp and dp.
//!
//! Run: `cargo bench --bench fig6_small`

use perks::harness;
use perks::simgpu::device::{a100, v100};

fn main() {
    for (elem, name) in [(4usize, "single precision"), (8, "double precision")] {
        println!("Fig 6 — small (fully cached) domains, {name}\n");
        print!("{}", harness::render_stencil_speedups(&[a100(), v100()], elem, true));
        println!();
    }
    println!("paper: 2D small domains 2.48x (A100) / 3.15x (V100); 3D 1.45x / 1.94x");
}
