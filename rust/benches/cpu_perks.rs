//! Persistent-threads CPU bench: the PERKS execution model measured
//! physically (thread-local slabs = on-chip cache, shared array = global
//! memory, GridBarrier = grid.sync). Sweeps domain size to expose the
//! strong-scaling effect: the smaller the per-thread state relative to
//! the core's cache, the larger the PERKS win — Fig 6's mechanism.
//!
//! Run: `cargo bench --bench cpu_perks`

use perks::stencil::{parallel, shape, Domain};
use perks::util::fmt::{bytes, secs, Table};
use perks::util::stats::{median, time_n};

fn main() {
    let threads = 8;
    let steps = 32;
    println!("CPU persistent-threads PERKS (threads={threads}, steps={steps}, median of 3)\n");
    let mut t = Table::new(&[
        "bench",
        "domain",
        "host-loop",
        "persistent",
        "speedup",
        "traffic host-loop",
        "traffic persistent",
    ]);
    let cases = [
        ("2d5pt", vec![256usize, 256]),
        ("2d5pt", vec![512, 512]),
        ("2d5pt", vec![1024, 1024]),
        ("2d9pt", vec![512, 512]),
        ("2ds9pt", vec![512, 512]),
        ("3d7pt", vec![64, 64, 64]),
        ("3d27pt", vec![64, 64, 64]),
        ("poisson", vec![64, 64, 64]),
    ];
    for (bench, interior) in cases {
        let s = shape::spec(bench).unwrap();
        let mut d = Domain::for_spec(&s, &interior).unwrap();
        d.randomize(3);
        let th = median(&time_n(3, || {
            parallel::host_loop(&s, &d, steps, threads).unwrap();
        }));
        let tp = median(&time_n(3, || {
            parallel::persistent(&s, &d, steps, threads).unwrap();
        }));
        let rep_h = parallel::host_loop(&s, &d, steps, threads).unwrap();
        let rep_p = parallel::persistent(&s, &d, steps, threads).unwrap();
        t.row(&[
            bench.to_string(),
            interior.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x"),
            secs(th),
            secs(tp),
            format!("{:.2}x", th / tp),
            bytes(rep_h.global_bytes as f64),
            bytes(rep_p.global_bytes as f64),
        ]);
    }
    print!("{}", t.render());
    println!("\npersistent threads exchange only slab boundaries through the shared");
    println!("array; host-loop round-trips the whole domain every step.");
}
