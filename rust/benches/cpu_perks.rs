//! Persistent-threads CPU bench: the PERKS execution model measured
//! physically (thread-local slabs = on-chip cache, shared array = global
//! memory, GridBarrier = grid.sync). Sweeps domain size to expose the
//! strong-scaling effect (Fig 6's mechanism), then measures the
//! spawn-once `stencil::pool` runtime against the spawn-per-step
//! host-loop baseline through the session API and emits the result as
//! `BENCH_stencil.json` (+ a `BENCH {...}` stdout line), so the stencil
//! perf trajectory is tracked exactly like `fig7_cg`'s.
//!
//! Run: `cargo bench --bench cpu_perks` (`-- --quick` for the CI smoke
//! configuration, which still emits `BENCH_stencil.json` for the
//! perf-regression gate).

use perks::harness;
use perks::stencil::{parallel, shape, Domain};
use perks::util::fmt::{bytes, secs, Table};
use perks::util::stats::{median, time_n};

fn domain_sweep(threads: usize, steps: usize, quick: bool) {
    println!("CPU persistent-threads PERKS (threads={threads}, steps={steps}, median of 3)\n");
    let mut t = Table::new(&[
        "bench",
        "domain",
        "host-loop",
        "persistent",
        "speedup",
        "traffic host-loop",
        "traffic persistent",
    ]);
    let cases: Vec<(&str, Vec<usize>)> = if quick {
        vec![("2d5pt", vec![96usize, 96]), ("3d7pt", vec![16, 16, 16])]
    } else {
        vec![
            ("2d5pt", vec![256usize, 256]),
            ("2d5pt", vec![512, 512]),
            ("2d5pt", vec![1024, 1024]),
            ("2d9pt", vec![512, 512]),
            ("2ds9pt", vec![512, 512]),
            ("3d7pt", vec![64, 64, 64]),
            ("3d27pt", vec![64, 64, 64]),
            ("poisson", vec![64, 64, 64]),
        ]
    };
    for (bench, interior) in cases {
        let s = shape::spec(bench).unwrap();
        let mut d = Domain::for_spec(&s, &interior).unwrap();
        d.randomize(3);
        let th = median(&time_n(3, || {
            parallel::host_loop(&s, &d, steps, threads).unwrap();
        }));
        let tp = median(&time_n(3, || {
            parallel::persistent(&s, &d, steps, threads).unwrap();
        }));
        let rep_h = parallel::host_loop(&s, &d, steps, threads).unwrap();
        let rep_p = parallel::persistent(&s, &d, steps, threads).unwrap();
        t.row(&[
            bench.to_string(),
            interior.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x"),
            secs(th),
            secs(tp),
            format!("{:.2}x", th / tp),
            bytes(rep_h.global_bytes as f64),
            bytes(rep_p.global_bytes as f64),
        ]);
    }
    print!("{}", t.render());
    println!("\npersistent threads exchange only slab boundaries through the shared");
    println!("array; host-loop round-trips the whole domain every step.");
}

fn pooled_section(threads: usize, quick: bool) {
    let (bench, interior, steps) =
        if quick { ("2d5pt", "96x96", 8usize) } else { ("2d5pt", "512x512", 64usize) };
    println!(
        "\nSpawn-once stencil pool vs spawn-per-step host loop \
         ({bench} {interior}, {steps} steps, {threads} threads)\n"
    );
    let modes = harness::measure_cpu_stencil_modes(bench, interior, steps, threads).unwrap();
    let mut t = Table::new(&[
        "mode",
        "wall s",
        "launches",
        "advance spawns",
        "barriers/step",
        "global traffic",
        "redundancy",
        "cells/s",
    ]);
    for m in &modes {
        t.row(&[
            m.mode.name().into(),
            format!("{:.6}", m.wall_seconds),
            m.invocations.to_string(),
            m.advance_spawns.to_string(),
            format!("{:.2}", m.barriers_per_step(steps)),
            bytes(m.global_bytes as f64),
            format!("{:.2}x", m.redundancy),
            format!("{:.3e}", m.cells_per_sec),
        ]);
    }
    print!("{}", t.render());
    println!(
        "pooled persistent speedup over host-loop: {:.2}x (spawn-once + resident slabs)",
        modes[0].wall_seconds / modes[1].wall_seconds.max(1e-12)
    );
    let json: Vec<String> = modes.iter().map(|m| m.json()).collect();
    let payload = format!(
        "{{\"bench\":\"stencil\",\"case\":\"{bench}\",\"interior\":\"{interior}\",\
         \"steps\":{steps},\"threads\":{threads},\"modes\":[{}]}}",
        json.join(",")
    );
    println!("BENCH {payload}");
    match std::fs::write("BENCH_stencil.json", format!("{payload}\n")) {
        Ok(()) => println!("wrote BENCH_stencil.json"),
        Err(e) => eprintln!("could not write BENCH_stencil.json: {e}"),
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let threads = if quick { 2 } else { 8 };
    let steps = if quick { 8 } else { 32 };
    domain_sweep(threads, steps, quick);
    pooled_section(threads, quick);
}
