//! Fig 8: where to cache — IMP / SM / REG / BTH heatmap per stencil
//! benchmark (speedup over the SM-OPT baseline), A100 and V100.
//!
//! Run: `cargo bench --bench fig8_cache_location`

use perks::harness;
use perks::simgpu::device::{a100, v100};

fn main() {
    for dev in [a100(), v100()] {
        println!("Fig 8 — cache-location heatmap on {} (dp, large domains)\n", dev.name);
        print!("{}", harness::render_fig8(&dev, 8));
        println!();
    }
    println!("paper: BTH usually best; higher-order stencils prefer SM (register pressure).");
}
