//! Ablation: PERKS is orthogonal to temporal blocking (paper §I/§II-C).
//!
//! Part 1 measures the sequential story on the CPU substrate: plain
//! host-loop, plain PERKS, temporal blocking alone (relaunch every bt
//! steps), and temporal blocking composed with PERKS — plus the
//! redundancy growth with bt that limits temporal blocking.
//!
//! Part 2 measures the *resident* composition: the spawn-once
//! `stencil::pool` runtime advancing `bt` sub-steps per exchange epoch
//! (`SessionBuilder::temporal`), against pooled `bt = 1` and the
//! host-loop baseline — wall, barrier syncs, global traffic and measured
//! redundancy per degree, on domains banded thinly enough that epoch
//! batching also lowers the exchanged bytes. Emits the result as
//! `BENCH_temporal.json` (+ a `BENCH {...}` stdout line) so the temporal
//! perf trajectory is tracked like `cpu_perks`'s.
//!
//! Run: `cargo bench --bench temporal_ablation` (`-- --quick` for the CI
//! smoke configuration).

use perks::harness;
use perks::stencil::{gold, parallel, shape, temporal, Domain};
use perks::util::fmt::{bytes, secs, Table};
use perks::util::stats::{median, time_n};

fn sequential_section(quick: bool) {
    let s = shape::spec("2d5pt").unwrap();
    let size = if quick { 96 } else { 512 };
    let steps = if quick { 8 } else { 32 };
    let parts = if quick { 2 } else { 8 };
    let reps = if quick { 1 } else { 3 };
    let mut d = Domain::for_spec(&s, &[size, size]).unwrap();
    d.randomize(13);

    println!("Temporal-blocking ablation, 2d5pt {size}^2, {steps} steps, {parts} bands\n");

    // baselines measured on the threaded executor
    let th = median(&time_n(reps, || {
        parallel::host_loop(&s, &d, steps, parts).unwrap();
    }));
    let tp = median(&time_n(reps, || {
        parallel::persistent(&s, &d, steps, parts).unwrap();
    }));
    let rep_h = parallel::host_loop(&s, &d, steps, parts).unwrap();
    let rep_p = parallel::persistent(&s, &d, steps, parts).unwrap();

    let mut t =
        Table::new(&["scheme", "wall", "global traffic", "redundant compute", "vs host-loop"]);
    t.row(&[
        "host-loop".into(),
        secs(th),
        bytes(rep_h.global_bytes as f64),
        "1.00x".into(),
        "1.00x".into(),
    ]);
    t.row(&[
        "PERKS".into(),
        secs(tp),
        bytes(rep_p.global_bytes as f64),
        "1.00x".into(),
        format!("{:.2}x", th / tp),
    ]);
    for bt in [2usize, 4, 8] {
        let tt = median(&time_n(reps, || {
            temporal::run_2d(&s, &d, steps, bt, parts).unwrap();
        }));
        let rep = temporal::run_2d(&s, &d, steps, bt, parts).unwrap();
        assert!(temporal::check_against_gold(&s, &d, steps, &rep).unwrap() < 1e-12);
        t.row(&[
            format!("temporal bt={bt}"),
            secs(tt),
            bytes(rep.global_bytes as f64),
            format!("{:.2}x", rep.redundancy()),
            format!("{:.2}x", th / tt),
        ]);
        let tc = median(&time_n(reps, || {
            temporal::run_2d_perks(&s, &d, steps, bt, parts).unwrap();
        }));
        let repc = temporal::run_2d_perks(&s, &d, steps, bt, parts).unwrap();
        assert!(temporal::check_against_gold(&s, &d, steps, &repc).unwrap() < 1e-12);
        t.row(&[
            format!("temporal bt={bt} + PERKS"),
            secs(tc),
            bytes(repc.global_bytes as f64),
            format!("{:.2}x", repc.redundancy()),
            format!("{:.2}x", th / tc),
        ]);
    }
    print!("{}", t.render());
}

/// The resident composition: pooled epochs of bt sub-steps. The cases
/// band thinly enough (`band_planes < 2*bt*radius` at the deepest
/// degree) that batching the exchange into epochs stores each thin band
/// once per *epoch* instead of once per *step* — lower `global_bytes` on
/// top of the `2*ceil(steps/bt)` barrier reduction.
fn pooled_section(quick: bool) -> String {
    let threads = if quick { 2 } else { 8 };
    let steps = if quick { 16 } else { 64 };
    let degrees = [1usize, 2, 4];
    let cases: &[(&str, &str)] =
        if quick { &[("2d5pt", "12x256")] } else { &[("2d5pt", "48x2048"), ("2ds25pt", "64x512")] };

    println!(
        "\nPooled temporal composition: epoch-batched resident exchange \
         ({steps} steps, {threads} threads)\n"
    );
    let mut case_payloads = Vec::new();
    for &(bench, interior) in cases {
        // the composition must stay gold-exact at the deepest degree
        let s = shape::spec(bench).unwrap();
        let dims: Vec<usize> =
            interior.split('x').map(|v| v.parse().unwrap()).collect();
        let mut d = Domain::for_spec(&s, &dims).unwrap();
        d.randomize(42); // the session default seed: same domain as below
        let want = gold::run(&s, &d, steps).unwrap();
        let check = parallel::persistent_temporal(&s, &d, steps, threads, 4).unwrap();
        assert_eq!(check.result.data, want.data, "{bench}: pooled bt=4 diverged from gold");

        let modes =
            harness::measure_cpu_stencil_temporal(bench, interior, steps, threads, &degrees)
                .unwrap();
        println!("{bench} {interior}:");
        let mut t = Table::new(&[
            "mode",
            "wall s",
            "launches",
            "barriers",
            "barriers/step",
            "global traffic",
            "redundancy",
            "cells/s",
        ]);
        for m in &modes {
            let label = match m.mode {
                perks::session::ExecMode::HostLoop => "host-loop".to_string(),
                _ => format!("pooled bt={}", m.bt),
            };
            t.row(&[
                label,
                format!("{:.6}", m.wall_seconds),
                m.invocations.to_string(),
                m.barrier_syncs.to_string(),
                format!("{:.2}", m.barriers_per_step(steps)),
                bytes(m.global_bytes as f64),
                format!("{:.2}x", m.redundancy),
                format!("{:.3e}", m.cells_per_sec),
            ]);
        }
        print!("{}", t.render());
        let bt1 = &modes[1];
        let bt4 = modes.last().unwrap();
        println!(
            "  bt={} vs bt=1: {:.2}x wall, {:.2}x barriers, {:.2}x global bytes\n",
            bt4.bt,
            bt1.wall_seconds / bt4.wall_seconds.max(1e-12),
            bt1.barrier_syncs.max(1) as f64 / bt4.barrier_syncs.max(1) as f64,
            bt1.global_bytes as f64 / bt4.global_bytes.max(1) as f64,
        );
        let json: Vec<String> = modes.iter().map(|m| m.json()).collect();
        case_payloads.push(format!(
            "{{\"case\":\"{bench}\",\"interior\":\"{interior}\",\"modes\":[{}]}}",
            json.join(",")
        ));
    }
    format!(
        "{{\"bench\":\"temporal\",\"steps\":{steps},\"threads\":{threads},\"cases\":[{}]}}",
        case_payloads.join(",")
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    sequential_section(quick);
    let payload = pooled_section(quick);

    println!("\nanalytic redundancy growth (the paper's limit on temporal blocking):");
    for rad in [1usize, 2, 4] {
        let rs: Vec<String> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&bt| {
                format!("bt={bt}: {:.2}x", temporal::overlap_cost_2d(64, 64, rad, bt).redundancy())
            })
            .collect();
        println!("  radius {rad}: {}", rs.join("  "));
    }
    println!("\nPERKS composes with temporal blocking (same numerics, 2/bt barriers per");
    println!("step, and lower exchange traffic once bands are thinner than the epoch");
    println!("depth), while avoiding the redundant-compute growth that limits bt.");
    println!("BENCH {payload}");
    match std::fs::write("BENCH_temporal.json", format!("{payload}\n")) {
        Ok(()) => println!("wrote BENCH_temporal.json"),
        Err(e) => eprintln!("could not write BENCH_temporal.json: {e}"),
    }
}
