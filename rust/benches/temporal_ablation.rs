//! Ablation: PERKS is orthogonal to temporal blocking (paper §I/§II-C).
//!
//! Measures, on the CPU substrate: plain host-loop, plain PERKS, temporal
//! blocking alone (relaunch every bt steps), and temporal blocking
//! composed with PERKS — plus the redundancy growth with bt that limits
//! temporal blocking (the paper's argument for PERKS as the alternative).
//!
//! Run: `cargo bench --bench temporal_ablation`

use perks::stencil::{parallel, shape, temporal, Domain};
use perks::util::fmt::{bytes, secs, Table};
use perks::util::stats::{median, time_n};

fn main() {
    let s = shape::spec("2d5pt").unwrap();
    let size = 512;
    let steps = 32;
    let parts = 8;
    let mut d = Domain::for_spec(&s, &[size, size]).unwrap();
    d.randomize(13);

    println!("Temporal-blocking ablation, 2d5pt {size}^2, {steps} steps, {parts} bands\n");

    // baselines measured on the threaded executor
    let th = median(&time_n(3, || {
        parallel::host_loop(&s, &d, steps, parts).unwrap();
    }));
    let tp = median(&time_n(3, || {
        parallel::persistent(&s, &d, steps, parts).unwrap();
    }));
    let rep_h = parallel::host_loop(&s, &d, steps, parts).unwrap();
    let rep_p = parallel::persistent(&s, &d, steps, parts).unwrap();

    let mut t = Table::new(&["scheme", "wall", "global traffic", "redundant compute", "vs host-loop"]);
    t.row(&[
        "host-loop".into(),
        secs(th),
        bytes(rep_h.global_bytes as f64),
        "1.00x".into(),
        "1.00x".into(),
    ]);
    t.row(&[
        "PERKS".into(),
        secs(tp),
        bytes(rep_p.global_bytes as f64),
        "1.00x".into(),
        format!("{:.2}x", th / tp),
    ]);
    for bt in [2usize, 4, 8] {
        let tt = median(&time_n(3, || {
            temporal::run_2d(&s, &d, steps, bt, parts).unwrap();
        }));
        let rep = temporal::run_2d(&s, &d, steps, bt, parts).unwrap();
        assert!(temporal::check_against_gold(&s, &d, steps, &rep).unwrap() < 1e-12);
        t.row(&[
            format!("temporal bt={bt}"),
            secs(tt),
            bytes(rep.global_bytes as f64),
            format!("{:.2}x", rep.redundancy()),
            format!("{:.2}x", th / tt),
        ]);
        let tc = median(&time_n(3, || {
            temporal::run_2d_perks(&s, &d, steps, bt, parts).unwrap();
        }));
        let repc = temporal::run_2d_perks(&s, &d, steps, bt, parts).unwrap();
        assert!(temporal::check_against_gold(&s, &d, steps, &repc).unwrap() < 1e-12);
        t.row(&[
            format!("temporal bt={bt} + PERKS"),
            secs(tc),
            bytes(repc.global_bytes as f64),
            format!("{:.2}x", repc.redundancy()),
            format!("{:.2}x", th / tc),
        ]);
    }
    print!("{}", t.render());

    println!("\nanalytic redundancy growth (the paper's limit on temporal blocking):");
    for rad in [1usize, 2, 4] {
        let rs: Vec<String> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&bt| format!("bt={bt}: {:.2}x", temporal::overlap_cost_2d(64, 64, rad, bt).redundancy()))
            .collect();
        println!("  radius {rad}: {}", rs.join("  "));
    }
    println!("\nPERKS composes with temporal blocking (same numerics, less traffic),");
    println!("while avoiding the redundant-compute growth that limits bt.");
}
