//! SpMV ablation (§V-C): merge-based SpMV vs the naive row-split baseline
//! under row-length skew, and the cached-plan vs re-searched-plan delta
//! that motivates the paper's workload-caching policies.
//!
//! Run: `cargo bench --bench spmv_ablation`

use perks::sparse::csr::Csr;
use perks::sparse::gen;
use perks::spmv::{merge, naive};
use perks::util::fmt::{secs, Table};
use perks::util::rng::Rng;
use perks::util::stats::{median, time_n};

fn skewed_matrix(n: usize, seed: u64) -> Csr {
    // adversarial skew: the first few rows hold most of the nonzeros, so
    // a contiguous row split gives one worker nearly all the work —
    // merge-path's target case (naive row-split serializes on thread 0)
    let mut rng = Rng::new(seed);
    let mut trip = Vec::new();
    for i in 0..8.min(n) {
        for _ in 0..n / 2 {
            let j = rng.index(n);
            trip.push((i, j, 1.0 + rng.f64()));
        }
    }
    for i in 8..n {
        trip.push((i, rng.index(n), 1.0 + rng.f64()));
        trip.push((i, i, 10.0));
    }
    Csr::from_coo(n, n, trip).unwrap()
}

fn main() {
    let threads = 8;
    println!("SpMV ablation (threads = {threads}, median of 9)\n");
    let mut t = Table::new(&[
        "matrix",
        "nnz",
        "naive row-split",
        "merge-path",
        "merge speedup",
        "plan search cost",
    ]);
    let cases: Vec<(String, Csr)> = vec![
        ("poisson2d 512 (uniform)".into(), gen::poisson2d(512)),
        ("clustered fem 100k".into(), gen::clustered_spd(100_000, 40, 200, 3).unwrap()),
        ("skewed 100k (8 hot rows)".into(), skewed_matrix(100_000, 5)),
    ];
    for (name, a) in &cases {
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..a.n_cols).map(|_| rng.f64()).collect();
        let mut y = vec![0.0; a.n_rows];
        let tn = median(&time_n(9, || naive::spmv_parallel(a, &x, &mut y, threads)));
        let plan = merge::MergePlan::new(a, threads * 8);
        let tm = median(&time_n(9, || merge::spmv_parallel(a, &plan, &x, &mut y, threads)));
        let tp = median(&time_n(9, || {
            std::hint::black_box(merge::MergePlan::new(a, threads * 8));
        }));
        t.row(&[
            name.clone(),
            a.nnz().to_string(),
            secs(tn),
            secs(tm),
            format!("{:.2}x", tn / tm),
            secs(tp),
        ]);
    }
    print!("{}", t.render());
    println!("\nplan-search cost is what the paper's TB-level workload caching avoids");
    println!("re-paying every iteration.");
}
