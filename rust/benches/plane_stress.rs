//! Async submission-plane stress: thousands of concurrent farm tenants
//! multiplexed onto one or two front-end OS threads, every advance a
//! batched command graph. The serving claim under test: completion
//! futures + `LocalExecutor` remove the thread-per-waiter cost, graph
//! batching pins enqueue-side scheduler-lock acquisitions to one per
//! batch (`sched_lock_acquisitions == plane_batches`), and admission
//! control sheds nothing under healthy load — all while tenant state
//! stays bit-identical to a solo pool (asserted inside the harness).
//! Emits `BENCH_plane.json` (+ a `BENCH {...}` stdout line) for the CI
//! perf-regression gate (`tools: bench_check`).
//!
//! Run: `cargo bench --bench plane_stress` (`-- --quick` for the CI
//! smoke configuration; the full run drives 10k tenants on 2 threads).

use perks::harness;
use perks::util::fmt::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // tiny domains: the stress target is the submission plane, not the
    // stencil math — per-solve compute must not drown the plane cost
    let (bench, interior, steps, segments, rounds, workers) =
        if quick { ("2d5pt", "12x12", 2usize, 4usize, 2usize, 4usize) } else { ("2d5pt", "12x12", 2, 4, 2, 8) };
    let sweep: &[(usize, usize)] =
        if quick { &[(64, 1), (256, 1)] } else { &[(1_000, 1), (10_000, 2)] };

    println!(
        "Plane stress: async tenants over SolverFarm({workers} workers) via batched \
         command graphs ({bench} {interior}, {segments}x{steps}-step graphs, {rounds} rounds)\n"
    );
    let mut t = Table::new(&[
        "tenants",
        "fe threads",
        "solves/s",
        "batches",
        "sched locks",
        "sheds",
        "timeouts",
        "inflight peak",
        "admission spawns",
    ]);
    let mut rows = Vec::new();
    for &(tenants, frontend_threads) in sweep {
        let row = harness::plane_stress(
            bench,
            interior,
            steps,
            segments,
            rounds,
            workers,
            tenants,
            frontend_threads,
        )
        .unwrap();
        // the batched-path acceptance bars, enforced at measurement time
        assert_eq!(
            row.sched_lock_acquisitions, row.plane_batches,
            "graph batching leaked extra scheduler-lock acquisitions"
        );
        assert_eq!(row.plane_sheds, 0, "unbounded plane shed a submission");
        assert_eq!(row.plane_timeouts, 0, "unbounded plane timed out a submission");
        assert_eq!(row.admission_spawns, 0, "plane stress spawned threads per tenant");
        t.row(&[
            row.tenants.to_string(),
            row.frontend_threads.to_string(),
            format!("{:.1}", row.solves_per_sec),
            row.plane_batches.to_string(),
            row.sched_lock_acquisitions.to_string(),
            row.plane_sheds.to_string(),
            row.plane_timeouts.to_string(),
            row.inflight_peak.to_string(),
            row.admission_spawns.to_string(),
        ]);
        rows.push(row);
    }
    print!("{}", t.render());
    println!(
        "\nevery tenant is an async task awaiting a completion future; the scheduler\n\
         lock is taken once per graph batch, not once per epoch segment."
    );

    let json: Vec<String> = rows.iter().map(|r| r.json()).collect();
    let payload = format!(
        "{{\"bench\":\"plane\",\"case\":\"{bench}\",\"interior\":\"{interior}\",\
         \"steps\":{steps},\"segments\":{segments},\"rounds\":{rounds},\
         \"workers\":{workers},\"rows\":[{}]}}",
        json.join(",")
    );
    println!("BENCH {payload}");
    match std::fs::write("BENCH_plane.json", format!("{payload}\n")) {
        Ok(()) => println!("wrote BENCH_plane.json"),
        Err(e) => eprintln!("could not write BENCH_plane.json: {e}"),
    }
}
