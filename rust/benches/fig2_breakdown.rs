//! Fig 2: runtime decomposition (inter-step traffic vs compute) across the
//! optimization-level lineup, double precision 2d9pt 3072^2, 20 steps,
//! A100 — plus the speedup-if-50%-cached projection.
//!
//! Run: `cargo bench --bench fig2_breakdown`

use perks::simgpu::device::a100;
use perks::simgpu::opt;
use perks::simgpu::perfmodel::StencilScenario;
use perks::util::fmt::{secs, Table};

fn main() {
    let dev = a100();
    let scenario = StencilScenario {
        cells: 3072.0 * 3072.0,
        elem: 8,
        radius: 1,
        steps: 20,
        kernel_smem_per_cell: 2.0,
    };
    println!("Fig 2 — dp 2d9pt 3072^2, 20 steps, A100: runtime split by optimization\n");
    let rows = opt::fig2(&dev, &scenario);
    let mut t = Table::new(&["impl", "traffic", "compute", "total", "speedup if cache 50%"]);
    for r in &rows {
        t.row(&[
            r.level.name.to_string(),
            secs(r.traffic_seconds),
            secs(r.compute_seconds),
            secs(r.total_seconds()),
            format!("{:.2}x", r.speedup_cache_half),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper's message reproduced: the more optimized the kernel, the larger");
    println!("the share of inter-step traffic, hence the larger the caching win.");
}
