//! CG solver bench: host-loop vs persistent execution of the rust-native
//! CG through the `perks::session` CPU backend, on the Table V dataset
//! analogs (scaled), with iterates verified identical. The measured
//! deltas come from the two PERKS mechanisms the paper identifies for CG:
//! cached workload search and fused vector passes.
//!
//! Run: `cargo bench --bench cg_solver`

use perks::session::{Backend, ExecMode, Session, SessionBuilder};
use perks::sparse::datasets;
use perks::util::fmt::{secs, Table};
use perks::util::stats::{median, time_n};

fn main() {
    let iters = 60;
    println!("CG execution-model bench (fixed {iters} iterations, median of 3)\n");
    let mut t = Table::new(&["code", "rows", "nnz", "host-loop", "persistent", "speedup"]);
    for code in ["D1", "D3", "D7", "D8", "D12", "D15"] {
        let ds = datasets::by_code(code).unwrap();
        // scale down for bench runtime; density preserved
        let a = ds.generate(16).unwrap();
        let b = perks::sparse::gen::rhs(a.n_rows, 1);
        let build = |mode: ExecMode| -> Session {
            SessionBuilder::cg_system(a.clone(), b.clone())
                .parts(64)
                .threaded(a.n_rows > 20_000)
                .backend(Backend::cpu(1))
                .mode(mode)
                .build()
                .unwrap()
        };
        let mut h = build(ExecMode::HostLoop);
        let mut p = build(ExecMode::Persistent);
        let th = median(&time_n(3, || {
            h.run(iters).unwrap();
        }));
        let tp = median(&time_n(3, || {
            p.run(iters).unwrap();
        }));
        // verify identical iterates once
        let hx = h.state_f64().unwrap();
        let px = p.state_f64().unwrap();
        let diff = hx
            .iter()
            .zip(&px)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(diff < 1e-9, "{code}: iterates diverged by {diff}");
        t.row(&[
            code.to_string(),
            a.n_rows.to_string(),
            a.nnz().to_string(),
            secs(th),
            secs(tp),
            format!("{:.2}x", th / tp),
        ]);
    }
    print!("{}", t.render());
}
