//! CG solver bench: host-loop vs persistent execution of the rust-native
//! CG over merge-based SpMV on the Table V dataset analogs (scaled), with
//! iterates verified identical. The measured deltas come from the two
//! PERKS mechanisms the paper identifies for CG: cached workload search
//! and fused vector passes.
//!
//! Run: `cargo bench --bench cg_solver`

use perks::cg::{solve_host_loop, solve_persistent, CgOptions};
use perks::sparse::datasets;
use perks::util::fmt::{secs, Table};
use perks::util::stats::{median, time_n};

fn main() {
    let iters = 60;
    println!("CG execution-model bench (fixed {iters} iterations, median of 3)\n");
    let mut t = Table::new(&["code", "rows", "nnz", "host-loop", "persistent", "speedup"]);
    for code in ["D1", "D3", "D7", "D8", "D12", "D15"] {
        let ds = datasets::by_code(code).unwrap();
        // scale down for bench runtime; density preserved
        let a = ds.generate(16).unwrap();
        let b = perks::sparse::gen::rhs(a.n_rows, 1);
        let opts =
            CgOptions { max_iters: iters, tol: 0.0, parts: 64, threaded: a.n_rows > 20_000 };
        let th = median(&time_n(3, || {
            solve_host_loop(&a, &b, &opts).unwrap();
        }));
        let tp = median(&time_n(3, || {
            solve_persistent(&a, &b, &opts).unwrap();
        }));
        // verify identical iterates once
        let h = solve_host_loop(&a, &b, &opts).unwrap();
        let p = solve_persistent(&a, &b, &opts).unwrap();
        let diff = h
            .x
            .iter()
            .zip(&p.x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(diff < 1e-9, "{code}: iterates diverged by {diff}");
        t.row(&[
            code.to_string(),
            a.n_rows.to_string(),
            a.nnz().to_string(),
            secs(th),
            secs(tp),
            format!("{:.2}x", th / tp),
        ]);
    }
    print!("{}", t.render());
}
