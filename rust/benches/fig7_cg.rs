//! Fig 7: PERKS CG speedup over the Ginkgo-like baseline + the baseline's
//! sustained memory bandwidth, for the 20 Table V dataset analogs, split
//! by L2 capacity, on A100 and V100, sp and dp.
//!
//! Run: `cargo bench --bench fig7_cg`

use perks::harness;
use perks::simgpu::device::{a100, v100};

fn main() {
    for dev in [a100(), v100()] {
        for (elem, name) in [(4usize, "single"), (8, "double")] {
            println!("Fig 7 — CG on {} ({name} precision)\n", dev.name);
            print!("{}", harness::render_fig7(&dev, elem));
            println!();
        }
    }
    println!("paper: within-L2 geomeans 4.55/4.87x (A100 sp/dp), 4.32/5.05x (V100);");
    println!("beyond-L2 1.30/1.15x (A100), 1.44/1.59x (V100).");
}
