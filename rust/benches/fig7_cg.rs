//! Fig 7: PERKS CG speedup over the Ginkgo-like baseline + the baseline's
//! sustained memory bandwidth, for the 20 Table V dataset analogs, split
//! by L2 capacity, on A100 and V100, sp and dp — plus a **measured** CPU
//! section: the spawn-once persistent worker pool (`cg::pool`) against
//! the spawn-per-iteration host-loop baseline on a ≥64k-row Poisson
//! system, with wall seconds, launches and OS thread spawns.
//!
//! Run: `cargo bench --bench fig7_cg`

use perks::harness;
use perks::simgpu::device::{a100, v100};
use perks::util::fmt::Table;

fn measured_cpu_section() {
    let n = 65_536; // poisson2d(256)
    let iters = 40;
    let threads = 4;
    println!("Measured CPU CG — pooled persistent vs spawn-per-iteration host-loop");
    println!("({n}-row Poisson, {iters} fixed iterations, {threads} threads)\n");
    let modes = harness::measure_cpu_cg_modes(n, iters, threads, 64).unwrap();
    let mut t = Table::new(&["mode", "wall s", "launches", "advance spawns", "iters/s"]);
    for m in &modes {
        t.row(&[
            m.mode.name().into(),
            format!("{:.6}", m.wall_seconds),
            m.invocations.to_string(),
            m.advance_spawns.to_string(),
            format!("{:.1}", m.iters_per_sec),
        ]);
    }
    print!("{}", t.render());
    println!(
        "pooled persistent speedup over host-loop: {:.2}x (spawn-once + cached plan + fused passes)",
        modes[0].wall_seconds / modes[1].wall_seconds.max(1e-12)
    );
    let json: Vec<String> = modes.iter().map(|m| m.json()).collect();
    println!(
        "BENCH {{\"bench\":\"fig7_cpu_cg\",\"rows\":{n},\"iters\":{iters},\"threads\":{threads},\"modes\":[{}]}}",
        json.join(",")
    );
    println!();
}

fn main() {
    for dev in [a100(), v100()] {
        for (elem, name) in [(4usize, "single"), (8, "double")] {
            println!("Fig 7 — CG on {} ({name} precision)\n", dev.name);
            print!("{}", harness::render_fig7(&dev, elem));
            println!();
        }
    }
    measured_cpu_section();
    println!("paper: within-L2 geomeans 4.55/4.87x (A100 sp/dp), 4.32/5.05x (V100);");
    println!("beyond-L2 1.30/1.15x (A100), 1.44/1.59x (V100).");
}
