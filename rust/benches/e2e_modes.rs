//! Measured end-to-end bench: the three execution models through the real
//! PJRT stack, for every stencil artifact family plus CG. This is the
//! *measured* counterpart of the simulated Figs 5-7: the speedup SHAPE
//! (persistent > resident > host-loop; deeper fusion on smaller state)
//! must reproduce even though the substrate is CPU PJRT, not an A100.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench e2e_modes`

use perks::coordinator::{CgDriver, ExecMode, StencilDriver};
use perks::runtime::{HostTensor, Runtime};
use perks::sparse::gen;
use perks::stencil::{self, Domain};
use perks::util::fmt::{secs, Table};
use perks::util::stats::{median, time_n};

fn main() {
    let rt = match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: artifacts not available ({e}); run `make artifacts`");
            return;
        }
    };
    println!("E2E execution-model comparison on {} (median of 5)\n", rt.platform());

    let families = [
        ("2d5pt", "128x128", "f32", 64usize),
        ("2d9pt", "128x128", "f32", 64),
        ("2ds9pt", "128x128", "f32", 64),
        ("2d5pt", "64x64", "f64", 64),
        ("3d7pt", "32x32x32", "f32", 32),
        ("3d27pt", "32x32x32", "f32", 32),
    ];
    let mut t = Table::new(&[
        "bench",
        "host-loop",
        "resident",
        "persistent",
        "PERKS vs host-loop",
        "PERKS vs resident",
    ]);
    for (bench, interior, dtype, steps) in families {
        let driver = match StencilDriver::new(&rt, bench, interior, dtype) {
            Ok(d) => d,
            Err(_) => continue,
        };
        let spec = stencil::spec(bench).unwrap();
        let dims: Vec<usize> = interior.split('x').map(|d| d.parse().unwrap()).collect();
        let mut dom = Domain::for_spec(&spec, &dims).unwrap();
        dom.randomize(11);
        let padded: Vec<usize> = if spec.dims == 2 {
            vec![dom.padded[1], dom.padded[2]]
        } else {
            dom.padded.to_vec()
        };
        let x0 = match dtype {
            "f64" => HostTensor::f64(&padded, dom.data.clone()),
            _ => HostTensor::f32(&padded, dom.to_f32()),
        };
        let measure = |mode: ExecMode| {
            let times = time_n(5, || {
                driver.run(mode, &x0, steps).unwrap();
            });
            median(&times)
        };
        let h = measure(ExecMode::HostLoop);
        let r = measure(ExecMode::HostLoopResident);
        let p = measure(ExecMode::Persistent);
        t.row(&[
            format!("{bench} {interior} {dtype}"),
            secs(h),
            secs(r),
            secs(p),
            format!("{:.2}x", h / p),
            format!("{:.2}x", r / p),
        ]);
    }
    print!("{}", t.render());

    // CG
    println!("\nCG n=1024 (poisson 32x32), 64 iterations:");
    if let Ok(driver) = CgDriver::new(&rt, 1024) {
        let a = gen::poisson2d(32);
        let (data, cols, rows) = a.to_coo_f32();
        let data = HostTensor::f32(&[driver.nnz], data);
        let cols = HostTensor::i32(&[driver.nnz], cols);
        let rows = HostTensor::i32(&[driver.nnz], rows);
        let b: Vec<f32> = gen::rhs(1024, 7).iter().map(|&v| v as f32).collect();
        let mh = median(&time_n(5, || {
            driver.run(ExecMode::HostLoop, &data, &cols, &rows, &b, 64).unwrap();
        }));
        let mp = median(&time_n(5, || {
            driver.run(ExecMode::Persistent, &data, &cols, &rows, &b, 64).unwrap();
        }));
        println!("  host-loop {}   persistent {}   speedup {:.2}x", secs(mh), secs(mp), mh / mp);
    }
}
