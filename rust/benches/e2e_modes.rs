//! Measured end-to-end bench: the execution models through the
//! `perks::session` API — the spawn-once CPU stencil pool against the
//! relaunch-per-step baseline (no artifacts needed), then the three
//! models through the real PJRT stack for every stencil artifact family
//! plus CG. The PJRT half is the *measured* counterpart of the simulated
//! Figs 5-7: the speedup SHAPE (persistent > resident > host-loop;
//! deeper fusion on smaller state) must reproduce even though the
//! substrate is CPU PJRT, not an A100.
//!
//! PJRT section requires `make artifacts`. Run: `cargo bench --bench e2e_modes`

use std::rc::Rc;

use perks::harness;
use perks::runtime::Runtime;
use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::util::fmt::{secs, Table};
use perks::util::stats::{median, time_n};

/// Measured CPU section: the `stencil::pool` runtime (spawn-once, slabs
/// resident across advances) against spawn-per-step. Runs everywhere.
fn measured_cpu_stencil_section() {
    let threads = 4;
    println!("Measured CPU stencil — pooled persistent vs spawn-per-step host loop");
    println!("({threads} threads, via the session API)\n");
    let mut t = Table::new(&["bench", "mode", "wall s", "launches", "advance spawns"]);
    for (bench, interior, steps) in
        [("2d5pt", "128x128", 64usize), ("2d9pt", "128x128", 64), ("3d7pt", "32x32x32", 32)]
    {
        let modes =
            harness::measure_cpu_stencil_modes(bench, interior, steps, threads).unwrap();
        for m in &modes {
            t.row(&[
                format!("{bench} {interior}"),
                m.mode.name().into(),
                format!("{:.6}", m.wall_seconds),
                m.invocations.to_string(),
                m.advance_spawns.to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    println!();
}

fn main() {
    measured_cpu_stencil_section();
    let rt = match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => Rc::new(rt),
        Err(e) => {
            eprintln!("skipping PJRT section: artifacts not available ({e}); run `make artifacts`");
            return;
        }
    };
    println!("E2E execution-model comparison on {} (median of 5)\n", rt.platform());

    let families = [
        ("2d5pt", "128x128", "f32", 64usize),
        ("2d9pt", "128x128", "f32", 64),
        ("2ds9pt", "128x128", "f32", 64),
        ("2d5pt", "64x64", "f64", 64),
        ("3d7pt", "32x32x32", "f32", 32),
        ("3d27pt", "32x32x32", "f32", 32),
    ];
    let mut t = Table::new(&[
        "bench",
        "host-loop",
        "resident",
        "persistent",
        "PERKS vs host-loop",
        "PERKS vs resident",
    ]);
    for (bench, interior, dtype, steps) in families {
        let measure = |mode: ExecMode| -> Option<f64> {
            let mut session = SessionBuilder::stencil(bench, interior, dtype)
                .backend(Backend::pjrt(rt.clone()))
                .mode(mode)
                .seed(11)
                .build()
                .ok()?;
            let steps = session.aligned_steps(steps);
            let times = time_n(5, || {
                session.run(steps).unwrap();
            });
            Some(median(&times))
        };
        let (Some(h), Some(r), Some(p)) = (
            measure(ExecMode::HostLoop),
            measure(ExecMode::HostLoopResident),
            measure(ExecMode::Persistent),
        ) else {
            continue; // family not lowered in this artifact set
        };
        t.row(&[
            format!("{bench} {interior} {dtype}"),
            secs(h),
            secs(r),
            secs(p),
            format!("{:.2}x", h / p),
            format!("{:.2}x", r / p),
        ]);
    }
    print!("{}", t.render());

    // CG
    println!("\nCG n=1024 (poisson 32x32), 64 iterations:");
    let measure_cg = |mode: ExecMode| -> Option<f64> {
        let mut session = SessionBuilder::cg(1024)
            .backend(Backend::pjrt(rt.clone()))
            .mode(mode)
            .seed(7)
            .build()
            .ok()?;
        let iters = session.aligned_steps(64);
        let times = time_n(5, || {
            session.run(iters).unwrap();
        });
        Some(median(&times))
    };
    if let (Some(mh), Some(mp)) =
        (measure_cg(ExecMode::HostLoop), measure_cg(ExecMode::Persistent))
    {
        println!("  host-loop {}   persistent {}   speedup {:.2}x", secs(mh), secs(mp), mh / mp);
    }
}
