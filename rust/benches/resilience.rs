//! Resilience overhead + recovery bench: sweep the checkpoint cadence
//! over a farm stencil tenant and a farm CG tenant (clean arms — the
//! <5%-overhead acceptance bar for the default cadence), run one seeded
//! fault-recovery arm per workload (panic/NaN injected mid-run,
//! recovered from the last checkpoint, final state asserted
//! bit-identical to the clean run inside the harness), then repeat the
//! cadence sweep with **durable** crash-consistent snapshot persistence
//! enabled (`ResilienceConfig::durable` — tmp-write + fsync + atomic
//! rename per frame, off the scheduler lock). Durable rows carry
//! `"durable":1` and their own gates in `bench_check`: cadence 0
//! commits zero frames, clean arms never restore, and the default
//! cadence stays within 10% wall of its cadence-0 reference. Emits
//! `BENCH_resilience.json` (+ a `BENCH {...}` stdout line) for the CI
//! perf-regression gate (`tools: bench_check`).
//!
//! Run: `cargo bench --bench resilience` (`-- --quick` for the CI smoke
//! configuration).

use perks::util::fmt::Table;
use perks::{harness, runtime};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // cadence 0 first: it is the overhead reference every other arm is
    // gated against (and the bit-identity reference inside the sweep)
    let cadences: &[u64] = &[0, runtime::DEFAULT_CHECKPOINT_EVERY, 4, 1];
    let (interior, steps, bt, grid, iters, workers, reps) =
        if quick { ("48x48", 32usize, 2usize, 16usize, 24usize, 4usize, 2usize) }
        else { ("64x64", 96, 2, 23, 60, 8, 3) };

    println!(
        "Resilience: checkpoint cadence sweep + seeded fault recovery + durable arm \
         (stencil 2d5pt {interior} x{steps} steps bt={bt}; CG poisson {g}x{g} x{iters} iters; \
         {workers} workers)\n",
        g = grid
    );

    let mut rows = harness::stencil_cadence_sweep("2d5pt", interior, steps, bt, workers, cadences, reps)
        .unwrap();
    rows.extend(harness::cg_cadence_sweep(grid, iters, workers, cadences, reps).unwrap());
    rows.push(harness::stencil_recovery_row("2d5pt", interior, steps, bt, workers, 11).unwrap());
    rows.push(harness::cg_recovery_row(grid, iters, workers, 17).unwrap());

    // durable arm: same workloads and cadences, every checkpoint also
    // persisted crash-consistently; the harness asserts bit-identity and
    // the zero-frames-at-cadence-0 invariant before reporting
    let snap_dir = std::env::temp_dir().join(format!("perks-bench-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&snap_dir);
    rows.extend(
        harness::stencil_durable_sweep(
            "2d5pt", interior, steps, bt, workers, cadences, reps, &snap_dir.join("stencil"),
        )
        .unwrap(),
    );
    rows.extend(
        harness::cg_durable_sweep(grid, iters, workers, cadences, reps, &snap_dir.join("cg"))
            .unwrap(),
    );
    let _ = std::fs::remove_dir_all(&snap_dir);

    let mut t = Table::new(&[
        "case",
        "durable",
        "cadence",
        "wall ms",
        "overhead",
        "recoveries",
        "replayed",
        "ckpt KiB",
        "frames",
        "injected",
    ]);
    for row in &rows {
        // overhead vs the same case's cadence-0 reference arm (durable
        // rows compare against the durable cadence-0 arm)
        let base = rows
            .iter()
            .find(|r| r.case == row.case && r.cadence == 0 && r.durable == row.durable)
            .map(|r| r.wall_seconds)
            .unwrap_or(row.wall_seconds);
        let overhead = if row.injected > 0 {
            "-".to_string()
        } else {
            format!("{:+.1}%", (row.wall_seconds / base - 1.0) * 100.0)
        };
        t.row(&[
            row.case.clone(),
            if row.durable { "yes" } else { "-" }.to_string(),
            row.cadence.to_string(),
            format!("{:.2}", row.wall_seconds * 1e3),
            overhead,
            row.recoveries.to_string(),
            row.replayed_epochs.to_string(),
            format!("{:.1}", row.checkpoint_bytes as f64 / 1024.0),
            row.durable_frames.to_string(),
            row.injected.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nclean arms must never recover; the recovery arms replay from the last\n\
         checkpoint and land bit-identically on the clean run's state; the durable\n\
         arms additionally commit every checkpoint to disk (tmp + fsync + rename)\n\
         off the scheduler lock and must not change a single bit (all asserted in\n\
         the harness before any number is reported)."
    );

    let json: Vec<String> = rows.iter().map(|r| r.json()).collect();
    let payload = format!(
        "{{\"bench\":\"resilience\",\"interior\":\"{interior}\",\"steps\":{steps},\
         \"bt\":{bt},\"grid\":{grid},\"iters\":{iters},\"workers\":{workers},\
         \"reps\":{reps},\"rows\":[{}]}}",
        json.join(",")
    );
    println!("BENCH {payload}");
    match std::fs::write("BENCH_resilience.json", format!("{payload}\n")) {
        Ok(()) => println!("wrote BENCH_resilience.json"),
        Err(e) => eprintln!("could not write BENCH_resilience.json: {e}"),
    }
}
