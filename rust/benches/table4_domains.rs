//! Table IV: minimum domain sizes that saturate the device, per benchmark
//! x precision x device, from the Little's-law saturation model (see
//! simgpu::occupancy), printed next to the paper's empirical sizes.
//!
//! Run: `cargo bench --bench table4_domains`

use perks::simgpu::device::{a100, v100};
use perks::simgpu::occupancy::{min_domain_2d, min_domain_3d};
use perks::stencil::shape::catalog;
use perks::util::fmt::Table;

fn paper_a100_sp(bench: &str) -> &'static str {
    match bench {
        "2d5pt" | "2ds9pt" | "2d13pt" | "2d17pt" | "2d21pt" | "2d25pt" => "4608x3072",
        "2ds25pt" => "4608x4608",
        "2d9pt" => "3072x3072",
        _ => "256x288x256",
    }
}

fn main() {
    println!("Table IV — minimum saturating domain sizes (model vs paper)\n");
    for (elem, prec) in [(4usize, "single"), (8, "double")] {
        let mut t = Table::new(&["bench", "A100 (model)", "V100 (model)", "A100 paper (sp)"]);
        for s in catalog() {
            let (fa, fv) = if s.dims == 2 {
                let (ax, ay) = min_domain_2d(&a100(), elem, s.radius);
                let (vx, vy) = min_domain_2d(&v100(), elem, s.radius);
                (format!("{ax}x{ay}"), format!("{vx}x{vy}"))
            } else {
                let (ax, ay, az) = min_domain_3d(&a100(), elem, s.radius);
                let (vx, vy, vz) = min_domain_3d(&v100(), elem, s.radius);
                (format!("{ax}x{ay}x{az}"), format!("{vx}x{vy}x{vz}"))
            };
            t.row(&[
                s.name.to_string(),
                fa,
                fv,
                if elem == 4 { paper_a100_sp(s.name).to_string() } else { "-".into() },
            ]);
        }
        println!("{prec} precision:");
        print!("{}", t.render());
        println!();
    }
    println!("the model reproduces the magnitudes and the A100>V100, sp>dp ordering;");
    println!("the paper's exact values are empirical per-benchmark tunings.");
}
