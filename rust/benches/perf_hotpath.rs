//! Whole-stack hot-path profile (EXPERIMENTS.md §Perf).
//!
//! Measures the L3 hot paths in isolation so optimization deltas are
//! attributable: stencil cell-update kernels (gold + banded), merge SpMV,
//! CG vector passes, and PJRT literal marshalling.
//!
//! Run: `cargo bench --bench perf_hotpath`

use perks::sparse::gen;
use perks::spmv::merge;
use perks::stencil::{gold, parallel, shape, Domain};
use perks::util::fmt::Table;
use perks::util::rng::Rng;
use perks::util::stats::{median, time_n};

fn main() {
    let mut t = Table::new(&["hot path", "work", "median", "rate"]);

    // 1. gold stencil step (the reference cell-update kernel)
    for bench in ["2d5pt", "2d25pt", "3d7pt"] {
        let s = shape::spec(bench).unwrap();
        let interior: Vec<usize> = if s.dims == 2 { vec![512, 512] } else { vec![64, 64, 64] };
        let mut d = Domain::for_spec(&s, &interior).unwrap();
        d.randomize(3);
        let cells = d.interior_cells() as f64;
        let m = median(&time_n(5, || {
            std::hint::black_box(gold::run(&s, &d, 1).unwrap());
        }));
        t.row(&[
            format!("gold {bench}"),
            format!("{:.2}M cells/step", cells / 1e6),
            perks::util::fmt::secs(m),
            format!("{:.1} MCells/s", cells / m / 1e6),
        ]);
    }

    // 2. persistent-threads executor (per-step rate)
    {
        let s = shape::spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[512, 512]).unwrap();
        d.randomize(4);
        let steps = 16;
        let m = median(&time_n(3, || {
            parallel::persistent(&s, &d, steps, 4).unwrap();
        }));
        let cells = d.interior_cells() as f64 * steps as f64;
        t.row(&[
            "persistent 2d5pt x16".into(),
            format!("{:.2}M cells", cells / 1e6),
            perks::util::fmt::secs(m),
            format!("{:.1} MCells/s", cells / m / 1e6),
        ]);
    }

    // 3. merge SpMV
    {
        let a = gen::clustered_spd(200_000, 25, 120, 7).unwrap();
        let plan = merge::MergePlan::new(&a, 32);
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..a.n_cols).map(|_| rng.f64()).collect();
        let mut y = vec![0.0; a.n_rows];
        let m = median(&time_n(5, || merge::spmv(&a, &plan, &x, &mut y)));
        t.row(&[
            "merge spmv (seq)".into(),
            format!("{:.2}M nnz", a.nnz() as f64 / 1e6),
            perks::util::fmt::secs(m),
            format!("{:.1} Mnnz/s", a.nnz() as f64 / m / 1e6),
        ]);
    }

    // 4. CG fused vector pass (the L3 analog of the pallas kernel)
    {
        let n = 1_000_000usize;
        let mut rng = Rng::new(2);
        let mut x = vec![0.0f64; n];
        let mut r: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let p: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let ap: Vec<f64> = (0..n).map(|_| rng.f64() + 1.0).collect();
        let m = median(&time_n(5, || {
            let alpha = 0.01;
            let mut rr = 0.0;
            for i in 0..n {
                x[i] += alpha * p[i];
                let ri = r[i] - alpha * ap[i];
                r[i] = ri;
                rr += ri * ri;
            }
            std::hint::black_box(rr);
        }));
        t.row(&[
            "cg fused pass".into(),
            format!("{n} elems"),
            perks::util::fmt::secs(m),
            format!("{:.2} GB/s", (n * 8 * 5) as f64 / m / 1e9),
        ]);
    }

    // 5. PJRT literal marshalling (runtime edge)
    {
        use perks::runtime::{HostTensor, TensorSpec};
        let spec = TensorSpec::new(perks::runtime::DType::F32, &[1024, 1024]);
        let t0 = HostTensor::zeros(&spec);
        let m = median(&time_n(5, || {
            std::hint::black_box(t0.to_literal().unwrap());
        }));
        t.row(&[
            "host->literal 4MB".into(),
            "1024x1024 f32".into(),
            perks::util::fmt::secs(m),
            format!("{:.2} GB/s", 4e6 / m / 1e9),
        ]);
    }

    print!("{}", t.render());

    // 6. CG execution models on a 64k-row Poisson system: the spawn-once
    // worker pool (persistent) vs spawn-per-iteration SpMV (host-loop).
    // Reported per mode: wall seconds, launches, and OS thread spawns
    // during `advance` — the relaunch overhead PERKS eliminates.
    {
        let n = 65_536; // poisson2d(256): ≥64k rows, ~327k nnz
        let iters = 40;
        let threads = 4;
        println!("\nCG execution models ({n} rows, {iters} iters, {threads} threads)\n");
        let modes = perks::harness::measure_cpu_cg_modes(n, iters, threads, 64).unwrap();
        let mut ct = Table::new(&["mode", "wall", "launches", "spawns", "iters/s"]);
        for m in &modes {
            ct.row(&[
                m.mode.name().into(),
                perks::util::fmt::secs(m.wall_seconds),
                m.invocations.to_string(),
                m.advance_spawns.to_string(),
                format!("{:.1}", m.iters_per_sec),
            ]);
        }
        print!("{}", ct.render());
        let json: Vec<String> = modes.iter().map(|m| m.json()).collect();
        println!(
            "BENCH {{\"bench\":\"cg_pool_vs_hostloop\",\"rows\":{n},\"iters\":{iters},\"threads\":{threads},\"modes\":[{}]}}",
            json.join(",")
        );
    }
}
