//! Multi-tenant serving throughput: one shared `SolverFarm` vs a fresh
//! worker pool per session, swept over the concurrent-tenant count —
//! the Table II concurrency argument (launch/teardown dominates small
//! solves) applied to the serving path. Reports solves/sec, per-solve
//! p50/p99 latency (farm latency includes queueing — the serving view),
//! the farm's queue-wait percentiles and max/mean fairness ratio, and
//! the zero-spawn admission invariant. Emits `BENCH_farm.json` (+ a
//! `BENCH {...}` stdout line) for the CI perf-regression gate
//! (`tools: bench_check`).
//!
//! Run: `cargo bench --bench farm_throughput` (`-- --quick` for the CI
//! smoke configuration).

use perks::harness;
use perks::util::fmt::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (bench, interior, steps, rounds, workers) =
        if quick { ("2d5pt", "48x48", 8usize, 2usize, 4usize) } else { ("2d5pt", "64x64", 16, 3, 8) };
    let tenant_sweep: &[usize] = if quick { &[1, 4, 16] } else { &[1, 4, 16, 64] };

    println!(
        "Farm throughput: shared SolverFarm({workers} workers) vs pool-per-session \
         ({bench} {interior}, {steps} steps/solve, {rounds} rounds)\n"
    );
    let mut t = Table::new(&[
        "tenants",
        "farm solves/s",
        "solo solves/s",
        "speedup",
        "farm p50/p99 ms",
        "solo p50/p99 ms",
        "queue p50/p99 ms",
        "fairness",
        "admission spawns",
    ]);
    let mut rows = Vec::new();
    for &tenants in tenant_sweep {
        let row = harness::farm_vs_pool_per_session(bench, interior, steps, rounds, workers, tenants)
            .unwrap();
        // the multi-tenant acceptance bar, enforced at measurement time:
        // admitting + advancing sessions must not create threads
        assert_eq!(row.admission_spawns, 0, "farm admissions spawned threads");
        t.row(&[
            tenants.to_string(),
            format!("{:.1}", row.farm_solves_per_sec),
            format!("{:.1}", row.solo_solves_per_sec),
            format!("{:.2}x", row.speedup),
            format!("{:.2}/{:.2}", row.farm_p50_ms, row.farm_p99_ms),
            format!("{:.2}/{:.2}", row.solo_p50_ms, row.solo_p99_ms),
            format!("{:.3}/{:.3}", row.queue_p50_ms, row.queue_p99_ms),
            format!("{:.2}", row.fairness),
            row.admission_spawns.to_string(),
        ]);
        rows.push(row);
    }
    print!("{}", t.render());
    println!(
        "\nsmall solves batch onto the farm's resident workers instead of paying a\n\
         pool build/teardown per session; the win grows with the tenant count."
    );

    let json: Vec<String> = rows.iter().map(|r| r.json()).collect();
    let payload = format!(
        "{{\"bench\":\"farm\",\"case\":\"{bench}\",\"interior\":\"{interior}\",\
         \"steps\":{steps},\"rounds\":{rounds},\"workers\":{workers},\
         \"rows\":[{}]}}",
        json.join(",")
    );
    println!("BENCH {payload}");
    match std::fs::write("BENCH_farm.json", format!("{payload}\n")) {
        Ok(()) => println!("wrote BENCH_farm.json"),
        Err(e) => eprintln!("could not write BENCH_farm.json: {e}"),
    }
}
