//! Fig 1: performance + unused on-chip resources vs TB/SMX for the 2d9pt
//! dp stencil (3072^2) on A100. Regenerates both axes of the paper's
//! motivational figure and the 1.66x projected-speedup annotation.
//!
//! Run: `cargo bench --bench fig1_occupancy`

use perks::simgpu::concurrency;
use perks::simgpu::device::a100;
use perks::simgpu::occupancy::{self, KernelResources};
use perks::simgpu::perfmodel::{self, CacheSplit, StencilScenario, TileGeom};
use perks::util::fmt::Table;

fn main() {
    let dev = a100();
    // 2d9pt dp baseline kernel: 256 threads, 30 regs, one staged smem
    // plane block
    let kr = KernelResources { threads_per_tb: 256, regs_per_thread: 30, smem_per_tb: 18 * 1024 };
    let scenario = StencilScenario {
        cells: 3072.0 * 3072.0,
        elem: 8,
        radius: 1,
        steps: 20,
        kernel_smem_per_cell: 2.0,
    };
    let tile = TileGeom::tile_2d(256, 128);
    let peak_gcells = 74.6; // paper's measured peak for this kernel
    let c_hw = concurrency::c_hw_blended(&dev, 0.5);

    println!("Fig 1 — dp 2d9pt 3072^2 on A100: perf + unused resources vs TB/SMX\n");
    let mut t = Table::new(&[
        "TB/SMX",
        "GCells/s",
        "unused smem",
        "unused regs",
        "unused total",
        "projected PERKS speedup",
    ]);
    for tb in 1..=8 {
        let Some(occ) = occupancy::occupancy(&dev, &kr, tb) else {
            println!("TB/SMX={tb}: does not fit");
            continue;
        };
        // efficiency at this occupancy (per-TB ILP ~ 5000 independent
        // bytes: the dp 2d9pt kernel is heavily unrolled, so even one TB
        // keeps ~83% of peak — the paper's 62.0/74.6 at TB/SMX=1)
        let c_sw = 5000.0 * tb as f64;
        let eff = concurrency::efficiency(c_sw, c_hw);
        let gcells = peak_gcells * eff;
        // PERKS projection: cache as much of the domain as the freed
        // resources allow
        let split = CacheSplit {
            sm_bytes: occ.free_smem_bytes_device(&dev) as f64,
            reg_bytes: occ.free_reg_bytes_device(&dev) as f64 * 0.73,
        };
        let speedup = perfmodel::speedup(&dev, &scenario, &split, &tile, 1.0)
            * perfmodel::EFF_BASELINE; // projection, not measured: no perks derate
        t.row(&[
            tb.to_string(),
            format!("{gcells:.1}"),
            perks::util::fmt::bytes(occ.free_smem_bytes_device(&dev) as f64),
            perks::util::fmt::bytes(occ.free_reg_bytes_device(&dev) as f64),
            perks::util::fmt::bytes(occ.free_bytes_device(&dev) as f64),
            format!("{speedup:.2}x"),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper: perf drops 74.6 -> 62.0 GCells/s as TB/SMX -> 1 while >11.2 MB");
    println!("of on-chip memory frees up; caching there projects ~1.66x speedup.");
}
