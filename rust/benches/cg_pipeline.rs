//! The one-barrier-per-iteration claim, measured: classic pooled CG pays
//! two slot-ordered reduction barriers per iteration (p·Ap, then r·r);
//! pipelined CG (Ghysels–Vanroose fused recurrences) folds them into ONE
//! combined generation at the price of four auxiliary vector recurrences.
//! On small systems — where the barrier dominates the SpMV — the
//! collapsed sync is a wall win; on large systems the extra vector
//! traffic eats it, which is why `ExecPolicy::Auto` races the two.
//!
//! Both arms run through the session API on the persistent CPU pool, and
//! the reduction accounting is counter-asserted at the source: exactly
//! `2 * iters` generations for classic, exactly `iters` for pipelined,
//! zero thread spawns per advance for either. Emits the result as
//! `BENCH_cg_pipeline.json` (+ a `BENCH {...}` stdout line) for the
//! `pipelined-single-reduction` / `pipelined-wall-win` bench_check gates.
//!
//! Run: `cargo bench --bench cg_pipeline` (`-- --quick` for the CI smoke
//! configuration).

use perks::harness;
use perks::session::ExecMode;
use perks::util::fmt::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (ns, iters, threads, parts): (&[usize], usize, usize, usize) =
        if quick { (&[256, 576], 400, 4, 8) } else { (&[576, 1024, 2304], 600, 8, 16) };

    println!(
        "Pipelined CG ablation: classic (2 reductions/iter) vs pipelined \
         (1 reduction/iter), {iters} iters, {threads} threads, {parts} parts\n"
    );
    let mut t = Table::new(&["n", "mode", "wall s", "reductions", "reductions/iter", "iters/s"]);
    let mut rows = Vec::new();
    let mut headlines = Vec::new();
    for &n in ns {
        let arms = harness::measure_cpu_cg_pipeline(n, iters, threads, parts).unwrap();
        for a in &arms {
            // the invariant at the source, before it reaches bench_check:
            // classic folds twice per iteration, pipelined exactly once
            let want = match a.mode {
                ExecMode::Pipelined => iters as u64,
                _ => 2 * iters as u64,
            };
            assert_eq!(
                a.barrier_reductions, want,
                "n={n} {}: reduction accounting drifted",
                a.mode.key()
            );
            assert_eq!(a.advance_spawns, 0, "n={n} {}: resident arm spawned", a.mode.key());
            t.row(&[
                n.to_string(),
                a.mode.key().to_string(),
                format!("{:.6}", a.wall_seconds),
                a.barrier_reductions.to_string(),
                format!("{:.1}", a.barrier_reductions as f64 / iters as f64),
                format!("{:.3e}", a.iters_per_sec),
            ]);
            rows.push(a.json(n));
        }
        let classic = &arms[0];
        let pipe = &arms[1];
        headlines.push(format!(
            "  n={n}: pipelined is {:.2}x classic wall at half the reductions",
            classic.wall_seconds / pipe.wall_seconds.max(1e-12)
        ));
    }
    print!("{}", t.render());
    println!();
    for h in &headlines {
        println!("{h}");
    }

    let payload = format!(
        "{{\"bench\":\"cg_pipeline\",\"iters\":{iters},\"threads\":{threads},\
         \"parts\":{parts},\"rows\":[{}]}}",
        rows.join(",")
    );
    println!("BENCH {payload}");
    match std::fs::write("BENCH_cg_pipeline.json", format!("{payload}\n")) {
        Ok(()) => println!("wrote BENCH_cg_pipeline.json"),
        Err(e) => eprintln!("could not write BENCH_cg_pipeline.json: {e}"),
    }
}
