//! Table II: concurrency analysis of the sp 2d5pt kernel on A100
//! (1000 steps, 3072^2): TB/SMX vs used/unused registers, GM ops and
//! measured GCells/s — plus the §IV-D L2-concurrency investigation
//! (doubling C_sw at TB/SMX=1 recovers most of the gap), plus a
//! *measured* CPU counterpart: sweeping the resident worker count of the
//! spawn-once stencil pool (the CPU analog of TB/SMX) against the
//! relaunch baseline at the same concurrency.
//!
//! Run: `cargo bench --bench table2_concurrency`

use perks::harness;
use perks::simgpu::concurrency::{self, table_ii};
use perks::simgpu::device::a100;
use perks::util::fmt::{bytes, Table};

fn main() {
    let dev = a100();
    println!("Table II — sp 2d5pt on A100, 1000 steps, 3072^2\n");
    let rows = table_ii(&dev, 32, 256, 2580, 2048, 138.29, 0.6, &[1, 2, 8]);
    let mut t = Table::new(&[
        "TB/SMX",
        "used reg/SMX",
        "unused reg/SMX",
        "GM load op/SMX",
        "GM store op/SMX",
        "model GCells/s",
        "paper GCells/s",
    ]);
    let paper = [94.75, 133.24, 138.29];
    for (r, p) in rows.iter().zip(paper) {
        t.row(&[
            r.tb_per_smx.to_string(),
            bytes(r.used_reg_bytes as f64),
            bytes(r.unused_reg_bytes as f64),
            r.gm_load_ops.to_string(),
            r.gm_store_ops.to_string(),
            format!("{:.2}", r.projected_gcells),
            format!("{p:.2}"),
        ]);
    }
    print!("{}", t.render());

    // §IV-D: doubling the per-TB concurrency at TB/SMX=1
    let c_hw = concurrency::c_hw_blended(&dev, 0.6);
    let base = concurrency::efficiency((2580.0 + 2048.0) * 4.0 / 5.0, c_hw);
    let doubled = concurrency::efficiency(2.0 * (2580.0 + 2048.0) * 4.0 / 5.0, c_hw);
    println!(
        "\n§IV-D check: doubling C_sw at TB/SMX=1 lifts efficiency {:.1}% -> {:.1}%",
        100.0 * base,
        100.0 * doubled
    );
    println!("paper: 94.75 -> 123.94 GCells/s (68.5% -> 89.6% of saturated).");

    // measured CPU counterpart: resident worker concurrency sweep of the
    // spawn-once stencil pool (pooled advance spawns must read 0 at every
    // worker count; the baseline respawns workers * steps threads)
    println!("\nMeasured CPU concurrency sweep — 2d5pt 256x256, 32 steps\n");
    let mut ct = Table::new(&[
        "workers",
        "host-loop wall",
        "pooled wall",
        "speedup",
        "host advance spawns",
        "pooled advance spawns",
    ]);
    for threads in [1usize, 2, 4, 8] {
        let modes = harness::measure_cpu_stencil_modes("2d5pt", "256x256", 32, threads).unwrap();
        let (h, p) = (&modes[0], &modes[1]);
        ct.row(&[
            threads.to_string(),
            format!("{:.6}", h.wall_seconds),
            format!("{:.6}", p.wall_seconds),
            format!("{:.2}x", h.wall_seconds / p.wall_seconds.max(1e-12)),
            h.advance_spawns.to_string(),
            p.advance_spawns.to_string(),
        ]);
    }
    print!("{}", ct.render());

    // multi-tenant counterpart: many concurrent small sessions on one
    // shared SolverFarm vs a fresh pool per session — the same
    // launch/teardown-amortization argument at serving concurrency
    // (admission spawns must read 0: sessions reuse the farm's workers)
    println!("\nMulti-tenant farm sweep — 2d5pt 64x64, 16 steps/solve, 8 farm workers\n");
    let mut ft = Table::new(&[
        "tenants",
        "farm solves/s",
        "solo solves/s",
        "speedup",
        "queue p99 ms",
        "admission spawns",
    ]);
    for tenants in [2usize, 8, 16] {
        let row = harness::farm_vs_pool_per_session("2d5pt", "64x64", 16, 2, 8, tenants).unwrap();
        ft.row(&[
            tenants.to_string(),
            format!("{:.1}", row.farm_solves_per_sec),
            format!("{:.1}", row.solo_solves_per_sec),
            format!("{:.2}x", row.speedup),
            format!("{:.3}", row.queue_p99_ms),
            row.admission_spawns.to_string(),
        ]);
    }
    print!("{}", ft.render());
}
