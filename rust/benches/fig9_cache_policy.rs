//! Fig 9: what to cache in CG — IMP / VEC / MAT / MIX policy heatmap over
//! the Table V datasets, A100 and V100.
//!
//! Run: `cargo bench --bench fig9_cache_policy`

use perks::harness;
use perks::simgpu::device::{a100, v100};

fn main() {
    for dev in [a100(), v100()] {
        for (elem, name) in [(4usize, "single"), (8, "double")] {
            println!("Fig 9 — CG policy heatmap on {} ({name} precision)\n", dev.name);
            print!("{}", harness::render_fig9(&dev, elem));
            println!();
        }
    }
    println!("paper: IMP already 3.61x within L2 / 1.19x beyond; the greedy");
    println!("largest-arrays-first policy (MIX) is usually best.");
}
