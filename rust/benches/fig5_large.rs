//! Fig 5: PERKS speedup for all 13 stencil benchmarks at Table IV
//! (device-saturating) domain sizes, A100 + V100, sp and dp.
//!
//! Run: `cargo bench --bench fig5_large`

use perks::harness;
use perks::simgpu::device::{a100, v100};

fn main() {
    for (elem, name) in [(4usize, "single precision"), (8, "double precision")] {
        println!("Fig 5 — large domains, {name}\n");
        print!("{}", harness::render_stencil_speedups(&[a100(), v100()], elem, false));
        println!();
    }
    println!("paper: geomean 1.58x (A100 2D), 2.01x (V100 2D), 1.10x (A100 3D), 1.29x (V100 3D)");
}
